"""Chaos-hardened serving (serving/faults.py + runtime supervision).

The headline invariant: under ANY seeded fault schedule — injected
executor crashes, dropped/delayed inter-pool migrations, failed swap
DMAs, allocator pressure spikes, mid-stream client disconnects — every
SURVIVING request's token stream is bit-identical to the fault-free run,
in BOTH preemption modes, and no KV page leaks from any pool.  Recovery
reuses the machinery the equivalence tests already pin down (eviction +
recompute, swap demotion, migration re-routing), so chaos only reorders
WHEN work happens, never WHAT is computed.

Also covered: per-request deadlines, bounded retry budgets, the
graceful-degradation ladder, the no-progress diagnostic dump, the
FaultPlan seed/JSON determinism contract, and the fault-counter schema
shared by /metrics and the CI chaos gate.
"""

from __future__ import annotations

import asyncio
import json

import jax
import numpy as np
import pytest

from conftest import tiny_dense
from repro.core.base import make_scheduler
from repro.core.plan import RequestState, SubmitSpec
from repro.launch.load_gen import _fetch, _post_generate
from repro.models.model import DecoderModel
from repro.serving.engine import Engine, EngineHandoff
from repro.serving.faults import (DEGRADATION_LEVELS, DegradationLadder,
                                  FaultEvent, FaultInjector, FaultPlan)
from repro.serving.metrics import fault_counters, prometheus_text
from repro.serving.runtime import (DisaggRuntime, EngineExecutor,
                                   ServingRuntime)
from repro.serving.server import ServingServer
from repro.serving.traffic import TraceRequest


# ---------------------------------------------------------------- fixtures

def _mixed_trace(n=24, seed=0, spread=30):
    """Multi-class oversubscribed trace with iteration-indexed arrivals
    and real token ids (same idiom as tests/test_disagg.py)."""
    rng = np.random.default_rng(seed)
    arrivals = np.sort(rng.integers(0, spread, n)).astype(float)
    trace = []
    for i, t in enumerate(arrivals):
        n_tok = int(rng.integers(4, 10))
        trace.append(TraceRequest(
            arrival_time=float(t), prompt_len=n_tok,
            output_len=int(rng.integers(8, 13)),
            slo_class="batch" if i % 3 == 0 else "interactive",
            prompt_tokens=tuple(int(x)
                                for x in rng.integers(1, 200, n_tok))))
    return trace


def _engine(cfg, **eng_kw):
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler("layered", model.n_blocks, n_slots=4,
                           quantum=8, token_budget=16)
    return Engine(model, params, sched, n_slots=4, max_len=64, **eng_kw)


def _engine_pair(cfg, **eng_kw):
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched_kw = dict(n_slots=4, quantum=8, token_budget=16)
    sp = make_scheduler("layered", model.n_blocks, **sched_kw)
    sd = make_scheduler("decode", model.n_blocks, **sched_kw)
    common = dict(n_slots=4, max_len=64, **eng_kw)
    return Engine(model, params, sp, **common), \
        Engine(model, params, sd, **common)


def _free_outputs(cfg, trace):
    """Fault-free unconstrained reference run over the same prompts."""
    free = _engine(cfg)
    for tr in trace:
        free.submit(list(tr.prompt_tokens), tr.output_len,
                    slo_class=tr.slo_class)
    free.run(max_iterations=100_000)
    return free.outputs


def _assert_survivors_identical(requests, outputs, free_outputs):
    """Survivors bit-identical; shed requests' partial streams must be a
    PREFIX of the fault-free stream (greedy determinism)."""
    n_survivors = 0
    for r in requests:
        got = list(outputs.get(r.req_id, []))
        ref = list(free_outputs[r.req_id])
        if r.shed_reason is None:
            assert got == ref, f"survivor r{r.req_id} tokens diverged"
            n_survivors += 1
        else:
            assert got == ref[:len(got)], \
                f"shed r{r.req_id} stream is not a prefix"
    assert n_survivors > 0, "chaos schedule killed every request"


def _assert_no_leaks(*engines):
    for e in engines:
        assert e.alloc.pages_in_use() == 0
        assert e.alloc.host_pages_in_use() == 0
        e.alloc.check_invariants()


# ------------------------------------------------------- plan determinism

def test_fault_plan_seed_deterministic_and_json_round_trip(tmp_path):
    a = FaultPlan.from_seed(7)
    b = FaultPlan.from_seed(7)
    assert a.events == b.events and a.events
    assert FaultPlan.from_seed(8).events != a.events
    rt = FaultPlan.from_json(a.to_json())
    assert rt.events == a.events and rt.seed == a.seed
    # the three CLI spellings
    assert FaultPlan.load("seed:7").events == a.events
    assert FaultPlan.load(a.to_json()).events == a.events
    p = tmp_path / "plan.json"
    p.write_text(a.to_json())
    assert FaultPlan.load(f"@{p}").events == a.events


def test_fault_plan_rejects_garbage():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(iteration=1, kind="meteor_strike")
    with pytest.raises(ValueError, match="iteration"):
        FaultEvent(iteration=-1, kind="link_drop")
    with pytest.raises(ValueError, match="unknown FaultPlan fields"):
        FaultPlan.from_json('{"events": [], "typo": 1}')


def test_injector_events_stay_armed_until_due():
    plan = FaultPlan(events=[FaultEvent(iteration=5, kind="link_drop")])
    fi = FaultInjector(plan)
    assert fi.due("link_drop", 4) == []
    assert fi.armed("link_drop") == 1
    assert len(fi.due("link_drop", 9)) == 1      # late poll still fires
    assert fi.counters["n_link_drop"] == 1
    assert fi.exhausted()


# --------------------------------------------- survivor identity: crashes

@pytest.mark.parametrize("mode", ["recompute", "swap"])
def test_executor_crash_survivors_bit_identical(mode):
    """Injected executor-step crashes under memory pressure: every
    resident is evicted and recovered by recompute; with budget to spare,
    ALL requests survive with fault-free token streams."""
    cfg = tiny_dense()
    trace = _mixed_trace()
    eng = _engine(cfg, pages=16, page_size=4, decode_reserve=1,
                  preemption_mode=mode)
    plan = FaultPlan(events=[FaultEvent(iteration=4, kind="executor_crash"),
                             FaultEvent(iteration=15,
                                        kind="executor_crash")])
    fi = FaultInjector(plan)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration",
                        faults=fi, retry_budget=50)
    rr = rt.run(trace, max_iterations=100_000)

    assert fi.counters["n_executor_crash"] == 2
    assert rt.n_fault_retries > 0
    assert all(r.shed_reason is None for r in rr.requests)
    assert eng.outputs == _free_outputs(cfg, trace), \
        "crash recovery changed generated tokens"
    _assert_no_leaks(eng)
    stats = rt.fault_stats()
    assert stats["n_executor_crashes"] == 2
    assert stats["n_retry_sheds"] == 0


def test_swap_dma_failure_demotes_to_recompute_bit_identical():
    """A failed swap-out DMA batch demotes its victims to recompute
    evictions (host snapshot discarded pre-write) — tokens unchanged,
    swap accounting consistent, zero leaks."""
    cfg = tiny_dense()
    trace = _mixed_trace()
    eng = _engine(cfg, pages=16, page_size=4, decode_reserve=1,
                  preemption_mode="swap")
    # scheduled early; stays armed until an iteration actually swaps
    plan = FaultPlan(events=[FaultEvent(iteration=1,
                                        kind="swap_dma_fail")])
    fi = FaultInjector(plan)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration",
                        faults=fi, retry_budget=50)
    rr = rt.run(trace, max_iterations=100_000)

    assert fi.counters["n_swap_dma_fail"] == 1
    assert all(r.shed_reason is None for r in rr.requests)
    assert eng.outputs == _free_outputs(cfg, trace)
    # the demoted victims count as preemptions, not swaps
    assert sum(r.n_swaps for r in rr.requests) == rr.n_swap_outs
    _assert_no_leaks(eng)


@pytest.mark.parametrize("mode", ["recompute", "swap"])
def test_disagg_link_faults_survivors_bit_identical(mode):
    """Dropped and delayed inter-pool migrations plus a per-pool crash:
    victims fold and retry through the prefill pool (never lost), decode
    clock stays prefill-free, and the merged two-pool output equals the
    fault-free monolithic run."""
    cfg = tiny_dense()
    trace = _mixed_trace()
    ep, ed = _engine_pair(cfg, pages=16, page_size=4, decode_reserve=1,
                          preemption_mode=mode)
    bridge = EngineHandoff(ep, ed, streaming=True)
    plan = FaultPlan(events=[
        FaultEvent(iteration=2, kind="link_drop"),
        FaultEvent(iteration=6, kind="link_delay", magnitude=3.0),
        FaultEvent(iteration=10, kind="link_drop", target=1),
        FaultEvent(iteration=12, kind="executor_crash", target=0),
        FaultEvent(iteration=20, kind="executor_crash", target=1),
    ])
    fi = FaultInjector(plan)
    rt = DisaggRuntime(EngineExecutor(ep), EngineExecutor(ed), bridge,
                       clock="iteration", faults=fi, retry_budget=50)
    rr = rt.run(trace, max_iterations=100_000)

    assert fi.counters["n_link_drop"] == 2
    assert fi.counters["n_link_delay"] == 1
    assert fi.counters["n_executor_crash"] == 2
    assert rr.decode_prefill_slices == 0
    assert all(r.shed_reason is None for r in rr.requests)
    outs = {**ep.outputs, **ed.outputs}
    assert outs == _free_outputs(cfg, trace), \
        "link chaos changed generated tokens"
    _assert_no_leaks(ep, ed)


@pytest.mark.parametrize("mode", ["recompute", "swap"])
def test_seeded_chaos_schedule_survivors_bit_identical(mode):
    """The headline: a seeded multi-kind schedule (crashes, pressure
    spikes, disconnects, swap-DMA failures) against the oversubscribed
    trace — survivors bit-identical, shed streams are prefixes, zero
    pages leak.  Same seed, same chaos, every run."""
    cfg = tiny_dense()
    trace = _mixed_trace()
    free_outputs = _free_outputs(cfg, trace)
    plan = FaultPlan.from_seed(3, horizon=40, n_events=6,
                               kinds=["executor_crash", "pressure_spike",
                                      "client_disconnect",
                                      "swap_dma_fail"])
    eng = _engine(cfg, pages=16, page_size=4, decode_reserve=1,
                  preemption_mode=mode)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration",
                        faults=FaultInjector(plan), retry_budget=50)
    rr = rt.run(trace, max_iterations=100_000)

    assert rt.fault_stats()["n_injected_faults"] > 0
    _assert_survivors_identical(rr.requests, eng.outputs, free_outputs)
    _assert_no_leaks(eng)


# --------------------------------------------- deadlines, cancels, budget

def test_deadline_expiry_sheds_and_frees_kv():
    cfg = tiny_dense()
    rng = np.random.default_rng(0)
    specs = []
    for i in range(6):
        toks = tuple(int(x) for x in rng.integers(1, 200, 6))
        specs.append(SubmitSpec(
            prompt_tokens=toks, max_new_tokens=40, arrival_time=0.0,
            # the first two can never finish 40 tokens in 5 iterations
            deadline_ms=5 if i < 2 else None))
    eng = _engine(cfg)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration")
    rr = rt.run(specs, max_iterations=100_000)

    shed = [r for r in rr.requests if r.shed_reason == "deadline"]
    assert len(shed) == 2
    assert all(r.state is RequestState.DONE for r in shed)
    assert rt.n_deadline_sheds == 2
    done = [r for r in rr.requests if r.shed_reason is None]
    assert done and all(r.n_generated == 40 for r in done)
    _assert_no_leaks(eng)


def test_cancel_mid_run_sheds_and_notifies():
    """cancel() from another thread sheds at the next iteration boundary,
    fires on_shed in the loop thread, and frees the victim's pages."""
    cfg = tiny_dense()
    rng = np.random.default_rng(1)
    specs = [SubmitSpec(prompt_tokens=tuple(
        int(x) for x in rng.integers(1, 200, 6)),
        max_new_tokens=30, arrival_time=0.0) for _ in range(4)]
    eng = _engine(cfg)
    shed_log = []
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration",
                        on_shed=lambda r, why: shed_log.append(
                            (r.req_id, why)))
    rt.cancel(0)                    # queued before the loop even starts
    rt.cancel(999)                  # unknown id: ignored
    rr = rt.run(specs, max_iterations=100_000)

    assert shed_log == [(0, "disconnect")]
    assert rr.requests[0].shed_reason == "disconnect"
    assert rt.n_disconnect_sheds == 1
    assert all(r.shed_reason is None and r.n_generated == 30
               for r in rr.requests[1:])
    _assert_no_leaks(eng)


def test_retry_budget_exhaustion_sheds_with_reason():
    """retry_budget=0: the first injected crash sheds every resident with
    reason 'retries' instead of recovering it — bounded, never a loop."""
    cfg = tiny_dense()
    trace = _mixed_trace(n=8, spread=2)
    eng = _engine(cfg, pages=16, page_size=4, decode_reserve=1)
    plan = FaultPlan(events=[FaultEvent(iteration=3,
                                        kind="executor_crash")])
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration",
                        faults=FaultInjector(plan), retry_budget=0)
    rr = rt.run(trace, max_iterations=100_000)

    shed = [r for r in rr.requests if r.shed_reason == "retries"]
    assert shed, "crash with zero budget must shed residents"
    assert rt.n_retry_sheds == len(shed)
    assert rt.n_fault_retries == 0
    survivors = [r for r in rr.requests if r.shed_reason is None]
    assert survivors and all(r.finish_time is not None for r in survivors)
    _assert_no_leaks(eng)


def test_pressure_spike_forces_evictions_and_releases():
    """Phantom page reservations under an otherwise-fitting load force
    the eviction path; tokens unchanged and the phantom never leaks."""
    cfg = tiny_dense()
    trace = _mixed_trace(n=12, spread=10)
    eng = _engine(cfg, pages=24, page_size=4, decode_reserve=1)
    plan = FaultPlan(events=[
        FaultEvent(iteration=3, kind="pressure_spike", magnitude=16,
                   duration=8),
        FaultEvent(iteration=20, kind="pressure_spike", magnitude=16,
                   duration=8)])
    fi = FaultInjector(plan)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration",
                        faults=fi, retry_budget=50)
    rr = rt.run(trace, max_iterations=100_000)

    assert fi.counters["n_pressure_spike"] == 2
    assert all(r.shed_reason is None for r in rr.requests)
    assert eng.outputs == _free_outputs(cfg, trace)
    assert fi.exhausted()           # phantoms released at run end
    _assert_no_leaks(eng)


# ------------------------------------------------------ degradation ladder

def test_degradation_ladder_escalates_and_restores_spec():
    s = make_scheduler("layered", 4, n_slots=4, quantum=8,
                       token_budget=16)
    s.configure_speculation("ngram", 4, adaptive=True)
    lad = DegradationLadder([s], trip=2, window=4, cool=3)
    assert lad.level == "normal"

    def pressure_at(it):
        lad.record_pressure(it)
        lad.record_pressure(it)
        lad.step(it)

    pressure_at(1)
    assert lad.level == "spec_shrunk" and s.spec_k == 2
    pressure_at(2)
    assert lad.level == "spec_off" and s.spec_mode == "off"
    pressure_at(3)
    assert lad.level == "shed_batch"
    assert lad.shed_class("batch") and not lad.shed_class("interactive")
    pressure_at(4)
    assert lad.level == "interactive_503" and lad.refuse_new
    # one rung per step, even under continuing pressure at the top
    assert lad.n_escalations == 4
    # quiet cool-down walks back down and restores the saved spec config
    it = 4
    while lad.level != "normal":
        it += lad.cool
        lad.step(it)
    assert lad.n_deescalations == 4
    assert (s.spec_mode, s.spec_k, s.spec_adaptive) == ("ngram", 4, True)
    assert DEGRADATION_LEVELS[lad.level_index] == "normal"


def test_degradation_shed_batch_shows_in_run():
    """Sustained injected pressure climbs the ladder far enough to shed
    batch-class work; interactive requests still finish identically."""
    cfg = tiny_dense()
    trace = _mixed_trace()
    free_outputs = _free_outputs(cfg, trace)
    eng = _engine(cfg, pages=16, page_size=4, decode_reserve=1)
    events = [FaultEvent(iteration=i, kind="executor_crash")
              for i in range(2, 26, 2)]
    sched = eng.scheduler
    ladder = DegradationLadder([sched], trip=2, window=6, cool=50)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration",
                        faults=FaultInjector(FaultPlan(events=events)),
                        retry_budget=50, ladder=ladder)
    rr = rt.run(trace, max_iterations=100_000)

    stats = rt.fault_stats()
    assert stats["n_degradation_escalations"] >= \
        DEGRADATION_LEVELS.index("shed_batch")
    assert stats["n_degrade_sheds"] > 0
    assert any(r.shed_reason == "degrade" and r.slo_class == "batch"
               for r in rr.requests)
    _assert_survivors_identical(rr.requests, eng.outputs, free_outputs)
    _assert_no_leaks(eng)


# ------------------------------------------------- diagnostics + counters

def test_no_progress_dump_names_queues_pools_and_requests():
    cfg = tiny_dense()
    trace = _mixed_trace(n=8, spread=2)
    eng = _engine(cfg)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration")
    with pytest.raises(RuntimeError) as ei:
        rt.run(trace, max_iterations=3)
    msg = str(ei.value)
    assert "did not drain" in msg
    assert "pending_arrivals=" in msg
    assert "kv free=" in msg and "hwm=" in msg
    assert "[pool] sched=" in msg
    assert "\n  r" in msg, "per-request rows missing from the dump"


# ------------------------------------------------- HTTP server chaos

async def _with_server(body, **server_kw):
    cfg = tiny_dense()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler("layered", model.n_blocks, n_slots=4,
                           quantum=8, token_budget=16)
    eng = Engine(model, params, sched, n_slots=4, max_len=64)
    srv = ServingServer(eng, port=0, **server_kw)
    await srv.start()
    try:
        return await body(srv)
    finally:
        await srv.stop()


async def _open_sse(host, port, payload):
    """POST /v1/generate over a raw socket, consume the response head,
    return the live (reader, writer) mid-stream."""
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps(payload).encode()
    writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    status = int((await reader.readline()).split()[1])
    while (await reader.readline()) not in (b"\r\n", b"\n", b""):
        pass
    return reader, writer, status


def test_readyz_reflects_degradation_ladder():
    """/readyz flips to 503 once the ladder refuses new interactive
    work, and interactive POSTs are answered 503 at the front door."""
    async def body(srv):
        status, _ = await _fetch(srv.host, srv.port, "/readyz")
        assert status == 200
        lad = srv.runtime.ladder
        for it in range(1, 5):                 # one rung per iteration
            for _ in range(lad.trip):
                lad.record_pressure(it)
            lad.step(it)
        assert lad.level == "interactive_503" and lad.refuse_new
        status, raw = await _fetch(srv.host, srv.port, "/readyz")
        assert status == 503 and b"degraded" in raw
        status, headers, events = await _post_generate(
            srv.host, srv.port,
            {"prompt_tokens": [1, 2, 3], "max_new_tokens": 4})
        assert status == 503
        assert events[0][1]["error"].startswith("degraded")
        assert int(headers["retry-after"]) >= 1
        it = 4
        while lad.level != "normal":           # quiet cool-down recovers
            it += lad.cool
            lad.step(it)
        status, _ = await _fetch(srv.host, srv.port, "/readyz")
        assert status == 200

    asyncio.run(_with_server(body))


def test_drain_gates_ingestion_and_finishes_inflight():
    """While draining, /readyz fails and new POSTs answer 503; a stream
    already in flight when drain() is called completes intact, and the
    listener is torn down afterwards."""
    async def body(srv):
        srv._draining = True                   # the gate, deterministically
        status, raw = await _fetch(srv.host, srv.port, "/readyz")
        assert status == 503 and b"draining" in raw
        status, _, events = await _post_generate(
            srv.host, srv.port,
            {"prompt_tokens": [1, 2, 3], "max_new_tokens": 4})
        assert status == 503 and events[0][1]["error"] == "draining"
        srv._draining = False
        status, _ = await _fetch(srv.host, srv.port, "/readyz")
        assert status == 200

        fut = asyncio.ensure_future(_post_generate(
            srv.host, srv.port,
            {"prompt_tokens": [5, 6, 7, 8], "max_new_tokens": 24}))
        await asyncio.sleep(0.3)               # let it register a stream
        await srv.drain()
        status, _, events = await fut
        assert status == 200
        done = [d for k, d in events if k == "done"][0]
        assert "shed_reason" not in done and done["n_generated"] == 24
        with pytest.raises(OSError):
            await asyncio.open_connection(srv.host, srv.port)

    asyncio.run(_with_server(body, drain_timeout=60.0))


def test_sse_client_disconnect_cancels_and_frees_kv():
    """A client vanishing mid-SSE must cancel its generation: the engine
    thread sheds the request with reason 'disconnect', every KV page
    comes back, and the shed shows up in /metrics."""
    async def body(srv):
        reader, writer, status = await _open_sse(
            srv.host, srv.port,
            {"prompt_tokens": [9, 8, 7, 6, 5, 4], "max_new_tokens": 40})
        assert status == 200
        seen = 0
        while seen < 2:                        # two tokens, then vanish
            line = await reader.readline()
            assert line, "stream ended before any tokens"
            if line.startswith(b"event: token"):
                seen += 1
        writer.transport.abort()               # RST, not a polite FIN

        req = None
        for _ in range(1000):
            reqs = list(srv.engine.requests.values())
            if reqs and reqs[0].shed_reason == "disconnect" \
                    and srv.engine.alloc.pages_in_use() == 0:
                req = reqs[0]
                break
            await asyncio.sleep(0.01)
        assert req is not None, "disconnect never shed the request"
        assert req.n_generated < 40
        assert srv.n_dropped_streams == 1
        assert srv.n_shed_streams == 1
        assert srv.runtime.n_disconnect_sheds == 1
        srv.engine.alloc.check_invariants()
        status, raw = await _fetch(srv.host, srv.port, "/metrics")
        text = raw.decode()
        assert "repro_sheds_disconnect_total 1" in text
        assert "repro_shed_streams_total 1" in text

    asyncio.run(_with_server(body))


def test_fault_stats_schema_matches_prometheus_counters():
    cfg = tiny_dense()
    eng = _engine(cfg)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration")
    stats = rt.fault_stats()            # faults=None still yields schema
    counters = fault_counters(**stats)
    assert counters["faults_injected_total"] == 0.0
    assert counters["degradation_level"] == 0.0
    text = prometheus_text([], counters=counters)
    for name in ("repro_faults_injected_total",
                 "repro_fault_executor_crashes_total",
                 "repro_fault_link_drops_total",
                 "repro_fault_swap_dma_fails_total",
                 "repro_sheds_deadline_total",
                 "repro_sheds_retries_total",
                 "repro_sheds_disconnect_total",
                 "repro_fault_retries_total",
                 "repro_degradation_level"):
        assert f"{name} 0" in text, f"{name} missing from /metrics"
