"""Automatic prefix caching (DESIGN.md §Prefix caching): the refcounted
shared-page index on the allocator, and the engine acceptance bar — token
streams bit-identical cache-on vs cache-off under BOTH preemption modes,
with no page leaked and every refcount back to zero at drain.

The allocator property test interleaves admit / decode-growth / spec
reserve / swap / free over shared-prefix prompts with
``check_invariants`` after every step; the engine tests replay small
shared-prefix workloads (including the mixed-cohort packed regression:
a warm restored request admitted into the same layered cohort as a cold
full prompt, the shape that exposed ``_write_cache``'s clamped
dynamic-update-slice).
"""

from __future__ import annotations

import random

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to a deterministic seeded sweep
    from _hypothesis_fallback import given, settings, strategies as st

from conftest import tiny_dense, tiny_moe
from repro.core.base import make_scheduler
from repro.models.model import DecoderModel
from repro.serving.kvcache import PagedKVAllocator, PagedPoolExhausted
from repro.serving.engine import Engine

PS = 4        # allocator-test page size


def _alloc(n_pages=24, **kw):
    base = dict(n_pages=n_pages, page_size=PS, prefix_caching=True)
    base.update(kw)
    return PagedKVAllocator(**base)


def _prompt(rng, prefix, n_suffix):
    return list(prefix) + [int(x) for x in rng.integers(1, 97, n_suffix)]


def _admit_and_register(alloc, rid, prompt, decode=PS):
    hit = alloc.reserve(rid, len(prompt) + decode, prompt_tokens=prompt)
    alloc.set_length(rid, len(prompt))
    alloc.register_prefix(rid, prompt)
    return hit


# -- allocator unit tests ----------------------------------------------------


def test_chain_match_is_content_verified_and_page_aligned():
    alloc = _alloc()
    prompt = list(range(1, 11))               # 10 tokens: 2 full pages + 2
    _admit_and_register(alloc, 0, prompt)
    # longer prompt sharing both full pages hits exactly the full pages
    hit = alloc.lookup_prefix(prompt[:8] + [55, 56, 57, 58, 59])
    assert hit.cached_tokens == 8 and len(hit.pages) == 2 and not hit.cow
    # diverging inside the SECOND page only matches the first
    hit = alloc.lookup_prefix(prompt[:4] + [99] * 8)
    assert hit.cached_tokens == 4 and len(hit.pages) == 1
    # diverging in the first page misses entirely
    assert alloc.lookup_prefix([99] * 12).cached_tokens == 0


def test_fully_covered_prompt_drops_last_page_cow():
    alloc = _alloc()
    prompt = list(range(1, 9))                # exactly 2 full pages
    _admit_and_register(alloc, 0, prompt)
    hit = alloc.lookup_prefix(prompt)
    # the last matched page is dropped: its tokens re-prefill into a
    # private copy so the request still computes final logits, and the
    # hit only references pages that will be refcount-linked
    assert hit.cow and hit.cached_tokens == 4 and len(hit.pages) == 1
    assert hit.leaf is not None


def test_refcounts_link_park_and_revive():
    alloc = _alloc()
    prompt = list(range(1, 9)) + [20, 21]     # 2 full pages + tail
    _admit_and_register(alloc, 0, prompt)
    shared = [p for p in alloc.block_table(0) if p in alloc._page_digests]
    assert len(shared) == 2
    hit = alloc.reserve(1, len(prompt) + PS, prompt_tokens=prompt)
    assert hit.cached_tokens == 8
    assert all(alloc._refs[p] == 2 for p in shared)
    alloc.free(0)
    assert all(alloc._refs[p] == 1 for p in shared)
    alloc.free(1)
    # refcount 0: parked in the reclaimable LRU, still counted free
    assert all(alloc._refs[p] == 0 for p in shared)
    assert alloc.pages_in_use() == 0 and alloc.n_shared_pages == 2
    # a new hit revives the parked pages instead of reallocating
    hit = alloc.reserve(2, len(prompt) + PS, prompt_tokens=prompt)
    assert set(hit.pages) == set(shared)
    alloc.check_invariants()


def test_pool_pressure_reclaims_lru_and_notifies_engine():
    evicted = []
    alloc = _alloc(n_pages=6)
    alloc.on_prefix_evict = evicted.append
    prompt = list(range(1, 9))                # 2 shared pages once freed
    _admit_and_register(alloc, 0, prompt, decode=0)
    alloc.free(0)
    assert alloc.n_shared_pages == 2 and alloc.n_free_pages == 6
    # a cold reservation needing the whole pool must reclaim the LRU
    alloc.reserve(1, 6 * PS)
    assert alloc.n_shared_pages == 0 and len(evicted) == 2
    assert alloc.n_prefix_evictions == 2
    alloc.check_invariants()


def test_prefix_lru_pages_caps_retained_pages():
    evicted = []
    alloc = _alloc(prefix_lru_pages=1)
    alloc.on_prefix_evict = evicted.append
    prompt = list(range(1, 13))               # 3 full pages
    _admit_and_register(alloc, 0, prompt, decode=0)
    alloc.free(0)
    assert alloc.n_shared_pages == 1 and len(evicted) == 2
    alloc.check_invariants()


def test_register_is_idempotent_and_race_safe():
    alloc = _alloc()
    prompt = list(range(1, 9))
    alloc.reserve(0, len(prompt), prompt_tokens=prompt)
    alloc.set_length(0, len(prompt))
    first = alloc.register_prefix(0, prompt)
    assert [d for d, _ in first] and alloc.register_prefix(0, prompt) == []
    # a cohort mate that prefilled the same prompt privately loses the
    # race: its pages stay private, the index still serves request 0's
    alloc.reserve(1, len(prompt))
    alloc.set_length(1, len(prompt))
    assert alloc.register_prefix(1, prompt) == []
    before = dict(alloc._index)
    alloc.free(1)
    assert alloc._index == before
    alloc.check_invariants()


def test_swap_pins_shared_pages_in_hbm():
    alloc = _alloc(n_host_pages=24)
    prompt = list(range(1, 9)) + [30, 31]
    _admit_and_register(alloc, 0, prompt)
    hit = alloc.reserve(1, len(prompt) + PS, prompt_tokens=prompt)
    alloc.set_length(1, len(prompt))
    assert alloc.can_swap_out(1)
    moved = alloc.swap_out(1)
    # shared prefix pages never cross the host link: only private tokens
    assert moved == len(prompt) - hit.cached_tokens
    assert all(alloc._refs[p] >= 1 for p in hit.pages)
    alloc.check_invariants()
    alloc.swap_in(1)
    assert alloc.block_table(1)[:len(hit.pages)] == list(hit.pages)
    alloc.check_invariants()
    alloc.free(1)
    alloc.free(0)
    assert alloc.pages_in_use() == 0 and alloc.host_pages_in_use() == 0


# -- allocator property test -------------------------------------------------


@given(st.integers(min_value=0, max_value=10 ** 9))
@settings(max_examples=40, deadline=None)
def test_interleaved_lifecycle_never_leaks(seed):
    """Random admit/grow/spec/swap/free interleavings over shared-prefix
    prompts: page conservation holds after every operation, and at drain
    every refcount is zero with the whole pool reclaimable."""
    rng = np.random.default_rng(seed)
    pyrng = random.Random(seed)
    alloc = _alloc(n_pages=int(rng.integers(16, 40)),
                   n_host_pages=24, stash_factor=0.5,
                   prefix_lru_pages=pyrng.choice([None, 2, 6]))
    prefixes = [[int(x) for x in rng.integers(1, 97, 8)] for _ in range(3)]
    live, registered, next_rid = {}, set(), 0
    for _ in range(60):
        op = pyrng.choice(["admit", "grow", "spec", "swap_out",
                           "swap_in", "free"])
        try:
            if op == "admit":
                prompt = _prompt(rng, pyrng.choice(prefixes),
                                 int(rng.integers(0, 6)))
                rid, next_rid = next_rid, next_rid + 1
                alloc.reserve(rid, len(prompt) + PS,
                              stash_tokens=len(prompt) // 2,
                              prompt_tokens=prompt)
                alloc.set_length(rid, len(prompt))
                live[rid] = prompt
            elif op == "grow" and live:
                rid = pyrng.choice(sorted(live))
                if alloc.is_resident(rid):
                    alloc.grow_to(rid, alloc.length(rid) + 1)
                    if rid not in registered:
                        alloc.release_stash(rid)
                        alloc.register_prefix(rid, live[rid])
                        registered.add(rid)
            elif op == "spec" and live:
                rid = pyrng.choice(sorted(live))
                if alloc.is_resident(rid):
                    alloc.reserve_spec(rid, alloc.length(rid)
                                       + int(rng.integers(1, 2 * PS)))
                    alloc.release_spec(rid)
            elif op == "swap_out" and live:
                rid = pyrng.choice(sorted(live))
                if alloc.can_swap_out(rid):
                    alloc.swap_out(rid)
            elif op == "swap_in" and live:
                rid = pyrng.choice(sorted(live))
                if alloc.is_swapped(rid) and alloc.can_swap_in(rid):
                    alloc.swap_in(rid)
            elif op == "free" and live:
                rid = pyrng.choice(sorted(live))
                alloc.free(rid)
                live.pop(rid)
                registered.discard(rid)
        except PagedPoolExhausted:
            pass
        alloc.check_invariants()
    for rid in sorted(live):
        alloc.free(rid)
    alloc.check_invariants()
    assert alloc.pages_in_use() == 0
    assert all(r == 0 for r in alloc._refs.values())
    assert alloc.host_pages_in_use() == 0


# -- export/import property test ---------------------------------------------


@given(st.integers(min_value=0, max_value=10 ** 9))
@settings(max_examples=40, deadline=None)
def test_export_import_round_trip_never_leaks(seed):
    """Random interleavings of admit / grow / swap / export->import /
    free over a PAIR of allocators whose requests share prefix content
    (the disaggregated prefill->decode handoff, both directions): an
    exported request's pages live in the serialized payload — on NEITHER
    pool — until imported; chain refcounts travel with it; page
    conservation holds on both pools after every operation; and at drain
    both pools are fully reclaimable with every refcount zero."""
    rng = np.random.default_rng(seed)
    pyrng = random.Random(seed)
    pools = [_alloc(n_pages=int(rng.integers(24, 40)), n_host_pages=24),
             _alloc(n_pages=int(rng.integers(24, 40)), n_host_pages=24)]
    prefixes = [[int(x) for x in rng.integers(1, 97, 8)] for _ in range(3)]
    where, lengths, in_flight, next_rid = {}, {}, [], 0
    for _ in range(60):
        op = pyrng.choice(["admit", "grow", "swap_out", "export",
                           "import", "free"])
        try:
            if op == "admit":
                prompt = _prompt(rng, pyrng.choice(prefixes),
                                 int(rng.integers(0, 6)))
                rid, next_rid = next_rid, next_rid + 1
                side = pyrng.randint(0, 1)
                alloc = pools[side]
                alloc.reserve(rid, len(prompt) + PS, prompt_tokens=prompt)
                alloc.set_length(rid, len(prompt))
                alloc.register_prefix(rid, prompt)
                where[rid] = side
                lengths[rid] = len(prompt)
            elif op == "grow" and where:
                rid = pyrng.choice(sorted(where))
                alloc = pools[where[rid]]
                if alloc.is_resident(rid):
                    alloc.grow_to(rid, alloc.length(rid) + 1)
                    lengths[rid] = alloc.length(rid)
            elif op == "swap_out" and where:
                rid = pyrng.choice(sorted(where))
                alloc = pools[where[rid]]
                if alloc.can_swap_out(rid):
                    alloc.swap_out(rid)
            elif op == "export" and where:
                # export works from resident AND swapped residency; the
                # payload then holds the pages (in flight over the link)
                rid = pyrng.choice(sorted(where))
                src_side = where.pop(rid)
                exp = pools[src_side].export_pages(rid)
                assert exp.length == lengths[rid]
                in_flight.append((exp, 1 - src_side))
            elif op == "import" and in_flight:
                exp, dst_side = in_flight[0]
                dst = pools[dst_side]
                if dst.can_import(exp, exp.length + PS):
                    in_flight.pop(0)
                    dst.import_pages(exp, exp.length + PS)
                    assert dst.length(exp.req_id) == lengths[exp.req_id]
                    where[exp.req_id] = dst_side
            elif op == "free" and where:
                rid = pyrng.choice(sorted(where))
                pools[where.pop(rid)].free(rid)
                lengths.pop(rid)
        except PagedPoolExhausted:
            pass
        for alloc in pools:
            alloc.check_invariants()
    # drain: land every in-flight payload (pools empty out as we free)
    for rid in sorted(where):
        pools[where[rid]].free(rid)
    for exp, dst_side in in_flight:
        dst = pools[dst_side]
        assert dst.can_import(exp)
        dst.import_pages(exp)
        dst.free(exp.req_id)
    for alloc in pools:
        alloc.check_invariants()
        assert alloc.pages_in_use() == 0
        assert all(r == 0 for r in alloc._refs.values())
        assert alloc.host_pages_in_use() == 0


@given(st.integers(min_value=0, max_value=10 ** 9))
@settings(max_examples=40, deadline=None)
def test_export_import_with_injected_failures_never_leaks(seed):
    """The chaos variant of the round-trip property: the same
    pair-of-pools interleaving plus injected link failures — in-flight
    payloads DROPPED outright (the disagg link-drop fault), imports
    bounced back to the SOURCE pool (the whole-prompt-retry fallback in
    DisaggRuntime), and imports driven into ``PagedPoolExhausted``, which
    must leave the destination untouched and the payload importable
    later.  Export's move semantics mean a lost payload holds pages on
    NEITHER side, so even adversarial interleavings leak nothing."""
    rng = np.random.default_rng(seed)
    pyrng = random.Random(seed ^ 0x5EED)
    pools = [_alloc(n_pages=int(rng.integers(24, 40)), n_host_pages=24),
             _alloc(n_pages=int(rng.integers(24, 40)), n_host_pages=24)]
    prefixes = [[int(x) for x in rng.integers(1, 97, 8)] for _ in range(3)]
    where, in_flight, dropped, next_rid = {}, [], set(), 0
    for _ in range(70):
        op = pyrng.choice(["admit", "grow", "swap_out", "export",
                           "import", "drop", "bounce", "import_fail",
                           "free"])
        try:
            if op == "admit":
                prompt = _prompt(rng, pyrng.choice(prefixes),
                                 int(rng.integers(0, 6)))
                rid, next_rid = next_rid, next_rid + 1
                side = pyrng.randint(0, 1)
                alloc = pools[side]
                alloc.reserve(rid, len(prompt) + PS, prompt_tokens=prompt)
                alloc.set_length(rid, len(prompt))
                alloc.register_prefix(rid, prompt)
                where[rid] = side
            elif op == "grow" and where:
                rid = pyrng.choice(sorted(where))
                alloc = pools[where[rid]]
                if alloc.is_resident(rid):
                    alloc.grow_to(rid, alloc.length(rid) + 1)
            elif op == "swap_out" and where:
                rid = pyrng.choice(sorted(where))
                alloc = pools[where[rid]]
                if alloc.can_swap_out(rid):
                    alloc.swap_out(rid)
            elif op == "export" and where:
                rid = pyrng.choice(sorted(where))
                src_side = where.pop(rid)
                exp = pools[src_side].export_pages(rid)
                in_flight.append((exp, 1 - src_side, src_side))
            elif op == "drop" and in_flight:
                # link failure: the serialized payload is lost in flight;
                # nothing to release — export already freed the source
                exp, _, _ = in_flight.pop(
                    pyrng.randrange(len(in_flight)))
                dropped.add(exp.req_id)
            elif op == "bounce" and in_flight:
                # destination refused: retry lands the request back HOME
                exp, _, src_side = in_flight[0]
                src = pools[src_side]
                if src.can_import(exp, exp.length + PS):
                    in_flight.pop(0)
                    src.import_pages(exp, exp.length + PS)
                    where[exp.req_id] = src_side
            elif op == "import_fail" and in_flight:
                # an import that cannot fit must be atomic: raise without
                # mutating, leaving the payload importable later
                exp, dst_side, _ = in_flight[0]
                dst = pools[dst_side]
                impossible = (dst.n_pages + 8) * PS
                assert not dst.can_import(exp, impossible)
                before = dst.pages_in_use()
                with pytest.raises(PagedPoolExhausted):
                    dst.import_pages(exp, impossible)
                assert dst.pages_in_use() == before
            elif op == "import" and in_flight:
                exp, dst_side, _ = in_flight[0]
                dst = pools[dst_side]
                if dst.can_import(exp, exp.length + PS):
                    in_flight.pop(0)
                    dst.import_pages(exp, exp.length + PS)
                    where[exp.req_id] = dst_side
            elif op == "free" and where:
                rid = pyrng.choice(sorted(where))
                pools[where.pop(rid)].free(rid)
        except PagedPoolExhausted:
            pass
        for alloc in pools:
            alloc.check_invariants()
    for rid in sorted(where):
        pools[where[rid]].free(rid)
    # land the remaining payloads wherever they fit (pools are empty now)
    for exp, dst_side, src_side in in_flight:
        landed = next(p for p in (pools[dst_side], pools[src_side])
                      if p.can_import(exp))
        landed.import_pages(exp)
        landed.free(exp.req_id)
    for rid in dropped:
        assert not any(p.owns(rid) for p in pools)
    for alloc in pools:
        alloc.check_invariants()
        assert alloc.pages_in_use() == 0
        assert all(r == 0 for r in alloc._refs.values())
        assert alloc.host_pages_in_use() == 0


# -- engine bit-identity -----------------------------------------------------


def _shared_jobs(seed, n=6, prefix_len=24, suffix=4, out=4):
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(1, 97, prefix_len)) for _ in range(2)]
    return [(list(map(int, prefixes[int(rng.integers(2))]))
             + [int(x) for x in rng.integers(1, 97, suffix)], out)
            for _ in range(n)]


def _run_engine(cfg, jobs, **eng_kw):
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler("layered", model.n_blocks, n_slots=4, quantum=8,
                           token_budget=64)
    eng = Engine(model, params, sched, n_slots=4, max_len=64, page_size=4,
                 **eng_kw)
    for prompt, max_new in jobs:
        eng.submit(prompt, max_new)
    eng.run(max_iterations=100_000)
    return eng


@pytest.mark.parametrize("mode", ["recompute", "swap"])
def test_tokens_identical_cache_on_vs_off(mode):
    """The acceptance bar: identical greedy streams with caching on, in
    both preemption modes, with the cache actually hitting and the
    allocator fully drained (no leak, refcounts zero) at the end."""
    cfg = tiny_dense()
    jobs = _shared_jobs(0)
    kw = dict(pages=40, preemption=True, preemption_mode=mode,
              host_pages=160 if mode == "swap" else None, decode_reserve=0)
    off = _run_engine(cfg, jobs, prefix_cache=False, **kw)
    on = _run_engine(cfg, jobs, prefix_cache=True, **kw)
    assert {r: list(v) for r, v in on.outputs.items()} == \
           {r: list(v) for r, v in off.outputs.items()}
    assert on.alloc.n_prefix_hits > 0 and on.alloc.n_prefix_tokens > 0
    on.alloc.check_invariants()
    assert on.alloc.pages_in_use() == 0
    assert all(r == 0 for r in on.alloc._refs.values())


def test_mixed_cohort_packed_regression():
    """A warm restored request admitted into the SAME layered cohort as a
    cold full prompt: the warm row is bucket-padded to the cold row's
    window, so its KV write would slide below its offset under a clamped
    dynamic-update-slice and corrupt the restored prefix.  Guards the
    per-token scatter in models/attention._write_cache."""
    cfg = tiny_dense()
    rng = np.random.default_rng(7)
    pfx = [int(x) for x in rng.integers(1, 97, 24)]
    cold = [int(x) for x in rng.integers(1, 97, 28)]
    warm_sfx = [int(x) for x in rng.integers(1, 97, 4)]

    def run(cache_on):
        model = DecoderModel(cfg)
        params = model.init(jax.random.PRNGKey(0))
        sched = make_scheduler("layered", model.n_blocks, n_slots=4,
                               quantum=8, token_budget=64)
        eng = Engine(model, params, sched, n_slots=4, max_len=64,
                     page_size=4, packed=True, prefix_cache=cache_on)
        eng.submit(pfx + [int(x) for x in rng.integers(1, 97, 4)], 2)
        eng.run(max_iterations=10_000)       # registers the prefix
        eng.submit(cold, 2)                  # cold: other prompt
        eng.submit(pfx + warm_sfx, 2)        # warm: same cohort as cold
        eng.run(max_iterations=10_000)
        return {r: list(v) for r, v in eng.outputs.items()}

    rng_state = rng.bit_generator.state
    base = run(False)
    rng.bit_generator.state = rng_state      # same first-job suffix
    assert run(True) == base


def test_spec_decode_rides_shared_prefixes():
    """Speculative verify-k over a warm shared-prefix workload: streams
    stay identical to the non-speculating cache-off run (spec and prefix
    caching are both lossless, composed)."""
    cfg = tiny_moe()
    jobs = _shared_jobs(1, n=6, out=8)
    off = _run_engine(cfg, jobs, prefix_cache=False, spec_mode="off")
    on = _run_engine(cfg, jobs, prefix_cache=True, spec_mode="ngram",
                     spec_k=3)
    assert {r: list(v) for r, v in on.outputs.items()} == \
           {r: list(v) for r, v in off.outputs.items()}
    assert on.alloc.n_prefix_hits > 0
    on.alloc.check_invariants()
    assert all(r == 0 for r in on.alloc._refs.values())
