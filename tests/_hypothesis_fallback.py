"""Deterministic stand-in for ``hypothesis`` so property tests degrade to a
seeded parametrized sweep instead of killing the whole tier-1 run at
collection on machines without the dependency (see requirements-dev.txt).

Only the surface actually used by this test suite is implemented:
``given`` (positional and keyword strategies), ``settings(max_examples=...)``
and the strategies ``integers / floats / sampled_from / just / tuples /
lists`` plus ``.map`` / ``.flatmap``. Draws come from a ``random.Random``
seeded from the test's qualified name, so a failing example is reproducible
by rerunning the same test — no shrinking, but stable.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

DEFAULT_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def flatmap(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)).example(rng))


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (imported ``as st``)."""

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    @staticmethod
    def just(value):
        return _Strategy(lambda rng: value)

    @staticmethod
    def tuples(*ss):
        return _Strategy(lambda rng: tuple(s.example(rng) for s in ss))

    @staticmethod
    def lists(elem, min_size=0, max_size=10):
        return _Strategy(
            lambda rng: [elem.example(rng)
                         for _ in range(rng.randint(min_size, max_size))])


def settings(**kw):
    def deco(fn):
        fn._fallback_max_examples = kw.get("max_examples", DEFAULT_EXAMPLES)
        return fn
    return deco


def given(*gargs, **gkw):
    """Positional strategies fill the test's trailing parameters (matching
    hypothesis semantics); keyword strategies fill by name. Remaining
    parameters are hidden from the wrapper signature so pytest still
    resolves fixtures/parametrize args against them."""
    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        pos_names = names[len(names) - len(gargs):] if gargs else []
        supplied = set(pos_names) | set(gkw)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                DEFAULT_EXAMPLES))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {nm: s.example(rng) for nm, s in zip(pos_names, gargs)}
                drawn.update({k: s.example(rng) for k, s in gkw.items()})
                fn(*args, **kwargs, **drawn)

        wrapper.__signature__ = sig.replace(parameters=[
            p for p in sig.parameters.values() if p.name not in supplied])
        return wrapper
    return deco
