"""End-to-end serving driver: replay a Poisson trace through the
discrete-event simulator under every scheduler and print the paper's
headline metrics side by side (TTFT / TBT / SLO attainment / energy /
expert traffic).

Run:  PYTHONPATH=src python examples/serve_trace.py \
          [--model qwen3-30b-a3b] [--dataset arxiv] [--rate 1.3] [--n 150]
"""

from __future__ import annotations

import argparse

from repro.configs import get_config, list_configs
from repro.serving.cost_model import H100X2, TPU_V5E
from repro.serving.metrics import SLOConfig, request_metrics
from repro.serving.simulator import Simulator
from repro.serving.traffic import DATASETS, poisson_trace

SCHEDULERS = ("static", "continuous", "chunked", "layered", "hybrid")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="qwen3-30b-a3b",
                    choices=list_configs())
    ap.add_argument("--dataset", default="arxiv", choices=list(DATASETS))
    ap.add_argument("--rate", type=float, default=1.3)
    ap.add_argument("--n", type=int, default=150)
    ap.add_argument("--hw", default="h100x2", choices=["h100x2", "tpu_v5e"])
    ap.add_argument("--ttft-slo", type=float, default=10.0)
    ap.add_argument("--tbt-slo", type=float, default=0.125)
    args = ap.parse_args()

    cfg = get_config(args.model)
    hw = H100X2 if args.hw == "h100x2" else TPU_V5E
    trace = poisson_trace(DATASETS[args.dataset], args.rate, args.n, seed=0)
    slo = SLOConfig(args.ttft_slo, args.tbt_slo)

    print(f"{args.model} on {args.dataset} @ {args.rate} req/s "
          f"({args.n} requests, {hw.name})")
    hdr = (f"{'scheduler':<12}{'TTFT(s)':>9}{'p99':>8}{'TBT(ms)':>9}"
           f"{'p99':>8}{'SLO':>7}{'mJ/tok':>8}{'expert TB':>11}")
    print(hdr)
    print("-" * len(hdr))
    for name in SCHEDULERS:
        sim = Simulator(cfg, name, hw, n_slots=128,
                        token_budget=512, quantum=512)
        res = sim.run(trace)
        m = request_metrics(res.requests, slo)
        print(f"{name:<12}{m['ttft_mean']:>9.2f}{m['ttft_p99']:>8.2f}"
              f"{m['tbt_mean'] * 1e3:>9.1f}{m['tbt_p99'] * 1e3:>8.1f}"
              f"{m['slo_attainment']:>7.2f}"
              f"{res.energy_per_token * 1e3:>8.1f}"
              f"{res.total_expert_bytes / 1e12:>11.2f}")


if __name__ == "__main__":
    main()
