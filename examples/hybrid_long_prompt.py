"""§4.3 generalization demo: hybrid layered x chunked scheduling on a very
long prompt. Shows the three regimes side by side in the simulator:

  - chunked-512: stall-free but chunk-amplified expert loads;
  - pure layered: minimal expert loads, but per-iteration prefill work grows
    with prompt length once G hits the layer count;
  - hybrid (large chunks x layer groups): caps per-iteration work like
    chunked while keeping most of layered's reload savings — the knob the
    paper recommends for very long inputs (chunked pipeline parallelism).

Run:  PYTHONPATH=src python examples/hybrid_long_prompt.py
"""

from __future__ import annotations

from repro.configs import get_config
from repro.serving.cost_model import H100X2
from repro.serving.metrics import request_metrics
from repro.serving.simulator import Simulator
from repro.serving.traffic import TraceRequest

PROMPT = 65536          # 64k-token prompt
DECODERS = 16           # concurrent short requests decoding throughout


def main() -> None:
    cfg = get_config("qwen3-30b-a3b")
    trace = [TraceRequest(0.0, 512, 256) for _ in range(DECODERS)]
    trace.append(TraceRequest(5.0, PROMPT, 32))     # the long request

    print(f"{PROMPT}-token prompt + {DECODERS} decoding requests "
          "(Qwen3-30B-A3B, 2xH100 model)\n")
    hdr = (f"{'scheduler':<22}{'long-req TTFT(s)':>17}{'others p99 TBT(ms)':>20}"
           f"{'expert TB':>11}{'mJ/tok':>8}")
    print(hdr)
    print("-" * len(hdr))
    for name, kw in (
            ("chunked-512", dict(token_budget=512)),
            ("layered", dict(quantum=512)),
            ("hybrid-8k-chunks", dict(chunk_size=8192, quantum=512)),
    ):
        sched = name.split("-")[0] if "-" in name else name
        sched = {"chunked": "chunked", "layered": "layered",
                 "hybrid": "hybrid"}[sched]
        sim = Simulator(cfg, sched, H100X2, n_slots=32, **kw)
        res = sim.run(list(trace))
        long_req = max(res.requests, key=lambda r: r.prompt_len)
        others = [r for r in res.requests if r is not long_req]
        mo = request_metrics(others)
        print(f"{name:<22}{long_req.ttft():>17.2f}"
              f"{mo['tbt_p99'] * 1e3:>20.1f}"
              f"{res.total_expert_bytes / 1e12:>11.3f}"
              f"{res.energy_per_token * 1e3:>8.1f}")


if __name__ == "__main__":
    main()
