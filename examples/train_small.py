"""Train a ~100M-parameter MiniCPM-family model for a few hundred steps on
the synthetic corpus with the WSD schedule (MiniCPM's signature), with
checkpointing, and verify the loss drops.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""

from __future__ import annotations

import argparse
import dataclasses
import os

import jax

from repro.configs import get_config
from repro.models.model import DecoderModel
from repro.training.data import PackedDataset, SyntheticCorpus
from repro.training.optimizer import adamw
from repro.training.train import Trainer


def small_minicpm():
    """MiniCPM-2B scaled to ~100M params (keeps family structure: deep/thin,
    MHA, SwiGLU, tied embeddings)."""
    cfg = get_config("minicpm-2b")
    return dataclasses.replace(
        cfg, name="minicpm-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=8, head_dim=64, d_ff=1536, vocab_size=8192,
        max_seq_len=512).validate()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/minicpm100m.msgpack")
    args = ap.parse_args()

    cfg = small_minicpm()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{cfg.n_layers}L d={cfg.d_model}")

    opt = adamw(lr=6e-4, schedule="wsd", total_steps=args.steps, warmup=20)
    trainer = Trainer(model=model, opt=opt, params=params)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    ds = PackedDataset(corpus, seq_len=args.seq, batch_size=args.batch,
                       seed=0)
    hist = trainer.fit(iter(ds), steps=args.steps, log_every=20,
                       checkpoint_path=args.ckpt, checkpoint_every=100)
    for rec in hist:
        print(f"  step {rec['step']:>4}  loss {rec['ce']:.3f}  "
              f"lr {rec['lr']:.2e}  wall {rec['wall']:.0f}s")
    first, last = hist[0]["ce"], hist[-1]["ce"]
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'OK' if last < first - 0.5 else 'NO IMPROVEMENT'})")
    print(f"checkpoint: {args.ckpt} "
          f"({os.path.getsize(args.ckpt) / 1e6:.0f} MB)")


if __name__ == "__main__":
    main()
