"""Quickstart: build a tiny MoE model, serve three requests with LAYERED
PREFILL through the real engine, and print per-request latency plus the
expert-load savings vs chunked prefill.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.base import make_scheduler
from repro.models.model import DecoderModel
from repro.serving.engine import Engine


def build():
    # a reduced Qwen3-MoE-family model (same structure, CPU-sized)
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    cfg = dataclasses.replace(cfg, n_layers=4)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def serve(cfg, model, params, scheduler: str):
    sched = make_scheduler(scheduler, model.n_blocks, n_slots=4,
                           quantum=16, token_budget=32)
    eng = Engine(model, params, sched, n_slots=4, max_len=256)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
               for n in (48, 64, 24)]
    rids = [eng.submit(p, max_new_tokens=8) for p in prompts]
    eng.run()
    return eng, rids


def main() -> None:
    cfg, model, params = build()
    results = {}
    for scheduler in ("chunked", "layered"):
        eng, rids = serve(cfg, model, params, scheduler)
        results[scheduler] = eng
        print(f"\n=== {scheduler} prefill ===")
        for rid in rids:
            r = eng.requests[rid]
            toks = eng.outputs[rid]
            print(f"  req {rid}: prompt={r.prompt_len:3d} tok "
                  f"ttft_iter={r.ttft():4.0f} generated={toks}")
        print(f"  iterations: {eng.iteration}, "
              f"expert-load: {eng.expert_load_bytes / 1e6:.1f} MB")

    c, l = results["chunked"], results["layered"]
    assert c.outputs == l.outputs, "schedulers must agree on outputs!"
    print(f"\nidentical outputs; layered expert-load "
          f"{l.expert_load_bytes / max(c.expert_load_bytes, 1):.0%} "
          "of chunked (the paper's Table 7 mechanism, on a real router)")


if __name__ == "__main__":
    main()
