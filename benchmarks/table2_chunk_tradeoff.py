"""Paper Table 2: chunk-size trade-offs for Qwen on the arXiv workload.
Larger chunks improve runtime/energy/throughput but inflate tail TBT.

Request rates per chunk size follow the paper (rates chosen there to hold
TTFT ~2.5 s): 512 -> 1.3, 1024 -> 1.7, 2048 -> 2.6 req/s.
"""

from __future__ import annotations

from benchmarks.common import run_sim, save, table

ROWS_PAPER = {  # chunk: (rate, ttft_mean, ttft_p99, tbt_mean, tbt_p99, load_gb, mj_tok)
    512: (1.3, 2.68, 8.05, 29.0, 48.4, 955, 60.2),
    1024: (1.7, 2.32, 5.83, 43.6, 83.4, 631, 45.4),
    2048: (2.6, 2.56, 5.58, 73.6, 129, 304, 32.4),
}


def main(n_requests: int = 100) -> dict:
    rows = []
    for chunk, paper in ROWS_PAPER.items():
        m, res = run_sim("qwen3-30b-a3b", "arxiv", "chunked", paper[0],
                         n_requests=n_requests, token_budget=chunk)
        rows.append({
            "chunk": chunk, "rate": paper[0],
            "ttft_mean": m["ttft_mean"], "ttft_p99": m["ttft_p99"],
            "tbt_mean_ms": m["tbt_mean"] * 1e3,
            "tbt_p99_ms": m["tbt_p99"] * 1e3,
            "load_gb_req": m["expert_bytes_total"] / n_requests / 1e9,
            "mj_tok": m["energy_per_token_mj"],
            "paper_load": paper[5], "paper_mj": paper[6],
        })
    print(table(rows, ["chunk", "rate", "ttft_mean", "ttft_p99",
                       "tbt_mean_ms", "tbt_p99_ms", "load_gb_req",
                       "paper_load", "mj_tok", "paper_mj"],
                "Table 2 — chunk-size trade-offs (Qwen, arXiv)"))
    by = {r["chunk"]: r for r in rows}
    checks = {
        # larger chunks raise tail TBT sharply (paper: 48 -> 129 ms p99)
        "tbt_tail_grows": by[512]["tbt_p99_ms"] < by[1024]["tbt_p99_ms"]
        < by[2048]["tbt_p99_ms"],
        # energy/token falls ~46% from 512 to 2048 (paper: 60.2 -> 32.4)
        "energy_falls": by[2048]["mj_tok"] < 0.70 * by[512]["mj_tok"],
        # expert load falls with chunk size (paper: 955 -> 304 GB/req)
        "load_falls": by[2048]["load_gb_req"] < 0.45 * by[512]["load_gb_req"],
        # absolute load within 40% of the paper's measurement
        "load_magnitude": abs(by[512]["load_gb_req"] - 955) / 955 < 0.4,
    }
    print("\nchecks:", checks)
    result = {"rows": rows, "checks": checks, "pass": all(checks.values())}
    save("table2_chunk_tradeoff", result)
    return result


if __name__ == "__main__":
    main()
