"""Paper Figures 3+4: SLO attainment (end-to-end + TTFT/TBT breakdown)
under increasing request rates, chunked vs layered, for both models and
both workloads. The central Pareto-frontier claim.

``--oversubscribed`` adds the memory-pressure operating points: the page
pool is shrunk to ~3 average residents (benchmarks.common
.oversubscribed_pages) so admission queues and the pressure pass really
evicts, and each point runs under BOTH preemption modes (recompute vs
swap-to-host).  Rows gain queueing-delay / preemption-rate / swap-traffic
columns — the co-located regime the paper's TTFT-TBT tradeoff lives in.
"""

from __future__ import annotations

import argparse
import math

from benchmarks.common import run_sim, save, table


def _finite(x):
    """NaN -> None so the emitted artifact stays strict JSON (recompute
    rows have no restore latency; json.dump would write a bare NaN)."""
    return None if isinstance(x, float) and math.isnan(x) else x

# Rates extend past each scheduler's saturation point so the collapse is
# visible (the paper's Fig. 3 x-ranges, widened to the right).
SWEEPS = {
    ("qwen3-30b-a3b", "arxiv"): (1.3, 1.5, 1.7, 1.9, 2.1, 2.3, 2.6),
    ("qwen3-30b-a3b", "sharegpt"): (4.4, 4.8, 5.4, 6.0, 6.8),
    ("gpt-oss-20b", "arxiv"): (2.1, 2.5, 2.9, 3.3, 3.7),
    ("gpt-oss-20b", "sharegpt"): (6.2, 7.0, 7.8, 8.8, 9.8),
}

PREEMPTION_MODES = ("recompute", "swap")

# Columns the oversubscribed rows must carry (bench-smoke CI guards this
# schema so downstream plotting scripts can rely on it).
OVERSUB_COLUMNS = ("model", "dataset", "sched", "mode", "rate", "slo",
                   "queue_delay_mean", "queue_delay_p99", "preemption_rate",
                   "swap_rate", "swap_bytes", "swap_stall_time",
                   "restore_latency_mean", "pages_high_water")


def run_unconstrained(n_requests: int, sweeps) -> dict:
    all_rows = []
    for (model, dataset), rates in sweeps.items():
        for rate in rates:
            for sched in ("chunked", "layered"):
                m, res = run_sim(model, dataset, sched, rate,
                                 n_requests=n_requests)
                all_rows.append({
                    "model": model, "dataset": dataset, "sched": sched,
                    "rate": rate,
                    "slo": m["slo_attainment"],
                    "ttft_att": m["ttft_attainment"],
                    "tbt_att": m["tbt_attainment"],
                    "decode_batch": m["mean_decode_batch"],
                })
    print(table(all_rows, ["model", "dataset", "sched", "rate", "slo",
                           "ttft_att", "tbt_att", "decode_batch"],
                "Fig 3/4 — SLO attainment vs request rate"))

    # Checks: at every (model, dataset, rate), layered >= chunked - eps on
    # end-to-end SLO attainment; both keep TBT attainment ~1 in the stable
    # region; layered extends the >=90% operating region.
    def att(model, dataset, sched, rate):
        for r in all_rows:
            if (r["model"], r["dataset"], r["sched"], r["rate"]) == \
                    (model, dataset, sched, rate):
                return r
        raise KeyError

    pareto_ok = all(
        att(m_, d_, "layered", r_)["slo"] >= att(m_, d_, "chunked", r_)["slo"]
        - 0.02
        for (m_, d_), rates in sweeps.items() for r_ in rates)

    def max_stable_rate(model, dataset, sched):
        best = 0.0
        for r_ in sweeps[(model, dataset)]:
            if att(model, dataset, sched, r_)["slo"] >= 0.90:
                best = max(best, r_)
        return best

    capacity = {}
    for (m_, d_) in sweeps:
        lay, chk = (max_stable_rate(m_, d_, "layered"),
                    max_stable_rate(m_, d_, "chunked"))
        capacity[f"{m_}/{d_}"] = {"layered": lay, "chunked": chk}
    cap_ok = all(v["layered"] >= v["chunked"] for v in capacity.values())
    cap_gain = any(v["layered"] > v["chunked"] for v in capacity.values())

    checks = {"layered_pareto_dominates": pareto_ok,
              "layered_capacity_geq": cap_ok,
              "layered_capacity_strictly_better_somewhere": cap_gain}
    print("\ncapacity (max rate with >=90% SLO):", capacity)
    print("checks:", checks)
    return {"rows": all_rows, "capacity": capacity, "checks": checks}


def run_oversubscribed(n_requests: int, sweeps) -> dict:
    """Memory-pressure points: pool ~3 residents, both preemption modes."""
    rows = []
    for (model, dataset), rates in sweeps.items():
        # the pressure behaviour changes with load, not with every rate
        # step — sample the sweep's endpoints plus the midpoint
        picked = sorted({rates[0], rates[len(rates) // 2], rates[-1]})
        for rate in picked:
            for sched in ("chunked", "layered"):
                for mode in PREEMPTION_MODES:
                    m, res = run_sim(model, dataset, sched, rate,
                                     n_requests=n_requests,
                                     oversubscribed=True,
                                     preemption_mode=mode)
                    rows.append({
                        "model": model, "dataset": dataset, "sched": sched,
                        "mode": mode, "rate": rate,
                        "slo": _finite(m["slo_attainment"]),
                        "queue_delay_mean": _finite(m["queue_delay_mean"]),
                        "queue_delay_p99": _finite(m["queue_delay_p99"]),
                        "preemption_rate": _finite(m["preemption_rate"]),
                        "swap_rate": _finite(m["swap_rate"]),
                        "swap_bytes": res.swap_bytes,
                        "swap_stall_time": res.swap_stall_time,
                        "restore_latency_mean":
                            _finite(m["restore_latency_mean"]),
                        "pages_high_water": res.pages_high_water,
                    })
    print(table(rows, ["model", "dataset", "sched", "mode", "rate", "slo",
                       "queue_delay_mean", "preemption_rate", "swap_rate",
                       "swap_bytes", "swap_stall_time"],
                "Fig 3 (oversubscribed) — pool ~3 residents, "
                "recompute vs swap-to-host"))

    # Schema + behaviour checks: every row carries the full column set;
    # pressure really bit (somebody queued and somebody was evicted); swap
    # rows move bytes over the host link, recompute rows move none.
    schema_ok = all(all(c in r for c in OVERSUB_COLUMNS) for r in rows)
    pressured = any((r["preemption_rate"] or 0) > 0
                    or (r["swap_rate"] or 0) > 0 for r in rows)
    swap_traffic_ok = (
        all(r["swap_bytes"] == 0 for r in rows if r["mode"] == "recompute")
        and any(r["swap_bytes"] > 0 for r in rows if r["mode"] == "swap"))
    checks = {"oversub_schema": schema_ok,
              "oversub_pressure_bites": pressured,
              "oversub_swap_traffic": swap_traffic_ok}
    print("checks:", checks)
    return {"oversub_rows": rows, "oversub_columns": list(OVERSUB_COLUMNS),
            "checks": checks}


def main(n_requests: int = 400, oversubscribed: bool = False,
         smoke: bool = False) -> dict:
    sweeps = SWEEPS
    if smoke:
        # tiny CI-sized run: one model/dataset pair, two rates
        key = ("qwen3-30b-a3b", "sharegpt")
        sweeps = {key: SWEEPS[key][:2]}
        n_requests = min(n_requests, 24)
    result = run_unconstrained(n_requests, sweeps)
    if smoke:
        # a 24-request run at two pre-saturation rates cannot resolve a
        # capacity gap — both schedulers sit at 100% SLO attainment
        result["checks"].pop("layered_capacity_strictly_better_somewhere")
    if oversubscribed:
        over = run_oversubscribed(n_requests, sweeps)
        result["oversub_rows"] = over["oversub_rows"]
        result["oversub_columns"] = over["oversub_columns"]
        result["checks"].update(over["checks"])
    result["pass"] = all(result["checks"].values())
    save("fig3_slo_attainment", result)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--oversubscribed", action="store_true",
                    help="add memory-pressure points (pool ~3 residents) "
                         "sweeping both preemption modes")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (one sweep, <=24 requests)")
    args = ap.parse_args()
    main(n_requests=args.requests, oversubscribed=args.oversubscribed,
         smoke=args.smoke)
