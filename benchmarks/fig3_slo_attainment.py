"""Paper Figures 3+4: SLO attainment (end-to-end + TTFT/TBT breakdown)
under increasing request rates, chunked vs layered, for both models and
both workloads. The central Pareto-frontier claim.
"""

from __future__ import annotations

from benchmarks.common import run_sim, save, table

# Rates extend past each scheduler's saturation point so the collapse is
# visible (the paper's Fig. 3 x-ranges, widened to the right).
SWEEPS = {
    ("qwen3-30b-a3b", "arxiv"): (1.3, 1.5, 1.7, 1.9, 2.1, 2.3, 2.6),
    ("qwen3-30b-a3b", "sharegpt"): (4.4, 4.8, 5.4, 6.0, 6.8),
    ("gpt-oss-20b", "arxiv"): (2.1, 2.5, 2.9, 3.3, 3.7),
    ("gpt-oss-20b", "sharegpt"): (6.2, 7.0, 7.8, 8.8, 9.8),
}


def main(n_requests: int = 400) -> dict:
    all_rows = []
    for (model, dataset), rates in SWEEPS.items():
        for rate in rates:
            for sched in ("chunked", "layered"):
                m, res = run_sim(model, dataset, sched, rate,
                                 n_requests=n_requests)
                all_rows.append({
                    "model": model, "dataset": dataset, "sched": sched,
                    "rate": rate,
                    "slo": m["slo_attainment"],
                    "ttft_att": m["ttft_attainment"],
                    "tbt_att": m["tbt_attainment"],
                    "decode_batch": m["mean_decode_batch"],
                })
    print(table(all_rows, ["model", "dataset", "sched", "rate", "slo",
                           "ttft_att", "tbt_att", "decode_batch"],
                "Fig 3/4 — SLO attainment vs request rate"))

    # Checks: at every (model, dataset, rate), layered >= chunked - eps on
    # end-to-end SLO attainment; both keep TBT attainment ~1 in the stable
    # region; layered extends the >=90% operating region.
    def att(model, dataset, sched, rate):
        for r in all_rows:
            if (r["model"], r["dataset"], r["sched"], r["rate"]) == \
                    (model, dataset, sched, rate):
                return r
        raise KeyError

    pareto_ok = all(
        att(m_, d_, "layered", r_)["slo"] >= att(m_, d_, "chunked", r_)["slo"]
        - 0.02
        for (m_, d_), rates in SWEEPS.items() for r_ in rates)

    def max_stable_rate(model, dataset, sched):
        best = 0.0
        for r_ in SWEEPS[(model, dataset)]:
            if att(model, dataset, sched, r_)["slo"] >= 0.90:
                best = max(best, r_)
        return best

    capacity = {}
    for (m_, d_) in SWEEPS:
        lay, chk = (max_stable_rate(m_, d_, "layered"),
                    max_stable_rate(m_, d_, "chunked"))
        capacity[f"{m_}/{d_}"] = {"layered": lay, "chunked": chk}
    cap_ok = all(v["layered"] >= v["chunked"] for v in capacity.values())
    cap_gain = any(v["layered"] > v["chunked"] for v in capacity.values())

    checks = {"layered_pareto_dominates": pareto_ok,
              "layered_capacity_geq": cap_ok,
              "layered_capacity_strictly_better_somewhere": cap_gain}
    print("\ncapacity (max rate with >=90% SLO):", capacity)
    print("checks:", checks)
    result = {"rows": all_rows, "capacity": capacity, "checks": checks,
              "pass": all(checks.values())}
    save("fig3_slo_attainment", result)
    return result


if __name__ == "__main__":
    main()
