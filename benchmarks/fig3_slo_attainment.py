"""Paper Figures 3+4: SLO attainment (end-to-end + TTFT/TBT breakdown)
under increasing request rates, chunked vs layered, for both models and
both workloads. The central Pareto-frontier claim.

``--oversubscribed`` adds the memory-pressure operating points: the page
pool is shrunk to ~3 average residents (benchmarks.common
.oversubscribed_pages) so admission queues and the pressure pass really
evicts, and each point runs under BOTH preemption modes (recompute vs
swap-to-host).  Rows gain queueing-delay / preemption-rate / swap-traffic
columns — the co-located regime the paper's TTFT-TBT tradeoff lives in.

``--multi-tenant`` adds the mixed-class operating points: an interactive
ShareGPT foreground (Poisson) co-located with a batch-class arXiv
background (bursty on/off arrivals) under an oversubscribed pool, so the
class-aware eviction walk (batch victims first) really differentiates.
Rows are emitted PER CLASS with TTFT/TBT/attainment breakdowns for
chunked vs layered — the per-class Pareto frontier.

``--spec {ngram,draft}`` adds the speculative verify-k frontier: chunked
vs layered × speculation off/on at sampled rates (analytic acceptance
``--spec-acceptance``), asserting token-count invariance, folded
iteration counts, and no SLO loss from speculation.  With ``--spec`` set,
the multi-tenant rows also run with speculation on and gain a per-class
``accept_rate`` column.

``--prefix`` adds the automatic-prefix-caching frontier: a shared-prefix
trace (K system prompts, Zipf reuse) swept chunked vs layered × cache
off/on, with TTFT / SLO / hit-rate / expert-traffic columns — the
"layered admission prices only the un-cached suffix" claim.  The
multi-tenant rows always carry per-class ``hit_rate`` /
``cached_tokens`` columns (each tenant class shares a system prompt).
"""

from __future__ import annotations

import argparse
import math

import numpy as np

from benchmarks.common import SLOS, run_sim, run_sim_trace, save, table
from repro.serving.traffic import (DATASETS, ClassSpec, TraceRequest,
                                   multi_class_trace, shared_prefix_trace)


def _finite(x):
    """NaN -> None so the emitted artifact stays strict JSON (recompute
    rows have no restore latency; json.dump would write a bare NaN)."""
    return None if isinstance(x, float) and math.isnan(x) else x

# Rates extend past each scheduler's saturation point so the collapse is
# visible (the paper's Fig. 3 x-ranges, widened to the right).
SWEEPS = {
    ("qwen3-30b-a3b", "arxiv"): (1.3, 1.5, 1.7, 1.9, 2.1, 2.3, 2.6),
    ("qwen3-30b-a3b", "sharegpt"): (4.4, 4.8, 5.4, 6.0, 6.8),
    ("gpt-oss-20b", "arxiv"): (2.1, 2.5, 2.9, 3.3, 3.7),
    ("gpt-oss-20b", "sharegpt"): (6.2, 7.0, 7.8, 8.8, 9.8),
}

PREEMPTION_MODES = ("recompute", "swap")

# Columns the oversubscribed rows must carry (bench-smoke CI guards this
# schema so downstream plotting scripts can rely on it).
OVERSUB_COLUMNS = ("model", "dataset", "sched", "mode", "rate", "slo",
                   "queue_delay_mean", "queue_delay_p99", "preemption_rate",
                   "swap_rate", "swap_bytes", "swap_dma_time",
                   "swap_stall_time", "restore_latency_mean",
                   "pages_high_water")

# Per-class columns of the multi-tenant rows (same CI schema guard).
# ``accept_rate`` is the per-class speculative acceptance (None when the
# run is not speculating); ``hit_rate``/``cached_tokens`` are the
# per-class prefix-cache metrics (each class shares a system prompt).
MT_COLUMNS = ("model", "sched", "mode", "rate", "slo_class", "n_requests",
              "ttft_p50", "ttft_p99", "tbt_p50", "tbt_p99", "ttft_att",
              "tbt_att", "slo", "queue_delay_p99", "preemption_rate",
              "swap_rate", "accept_rate", "hit_rate", "cached_tokens")

# Prefix-caching frontier rows (chunked vs layered x cache off/on over a
# shared-prefix trace).
PFX_COLUMNS = ("model", "sched", "cache", "rate", "n_requests", "ttft_mean",
               "ttft_p99", "slo", "hit_rate", "cached_tokens",
               "expert_bytes", "n_iterations")

# Shared-prefix operating points: prompts are 1536 shared + 256 fresh
# tokens (~86% reuse potential), rates chosen to straddle each model's
# cache-off saturation so the capacity reclaimed by caching is visible.
PFX_SWEEPS = {
    "qwen3-30b-a3b": (4.4, 6.0),
    "gpt-oss-20b": (6.2, 8.8),
}
PFX_PREFIX_LEN = 1536
PFX_SUFFIX_LEN = 256
PFX_OUTPUT_LEN = 128

# Speculative verify-k frontier rows (chunked vs layered x spec off/on).
SPEC_COLUMNS = ("model", "dataset", "sched", "spec", "rate", "slo",
                "ttft_att", "tbt_att", "acceptance_rate", "n_iterations",
                "total_generated")

# Disaggregated-serving frontier rows (chunked vs layered x stream/whole
# KV handoff between the prefill and decode pools; same CI schema guard).
DISAGG_COLUMNS = ("model", "dataset", "sched", "handoff", "rate", "slo",
                  "ttft_mean", "tbt_mean", "decode_tbt_mean",
                  "n_migrations", "n_returns", "link_bytes",
                  "link_stall_time", "handoff_wait_time",
                  "migration_queue_peak", "decode_prefill_slices")

# Long-prompt operating points: the arXiv prompts (~8k tokens) make the
# KV the link actually has to move big enough that streaming-vs-whole
# separates cleanly.
DISAGG_SWEEPS = {
    ("qwen3-30b-a3b", "arxiv"): (1.3, 2.1),
    ("gpt-oss-20b", "arxiv"): (2.1, 3.3),
}

# Multi-tenant operating points: total offered rate is split 70/30 between
# the interactive ShareGPT foreground and the bursty batch arXiv
# background (arXiv prompts are the memory hogs, so the batch class is
# also the natural eviction victim).
MT_SWEEPS = {
    "qwen3-30b-a3b": (3.0, 4.4),
    "gpt-oss-20b": (4.2, 6.2),
}
MT_BATCH_SHARE = 0.3


def run_unconstrained(n_requests: int, sweeps) -> dict:
    all_rows = []
    for (model, dataset), rates in sweeps.items():
        for rate in rates:
            for sched in ("chunked", "layered"):
                m, res = run_sim(model, dataset, sched, rate,
                                 n_requests=n_requests)
                all_rows.append({
                    "model": model, "dataset": dataset, "sched": sched,
                    "rate": rate,
                    "slo": m["slo_attainment"],
                    "ttft_att": m["ttft_attainment"],
                    "tbt_att": m["tbt_attainment"],
                    "decode_batch": m["mean_decode_batch"],
                })
    print(table(all_rows, ["model", "dataset", "sched", "rate", "slo",
                           "ttft_att", "tbt_att", "decode_batch"],
                "Fig 3/4 — SLO attainment vs request rate"))

    # Checks: at every (model, dataset, rate), layered >= chunked - eps on
    # end-to-end SLO attainment; both keep TBT attainment ~1 in the stable
    # region; layered extends the >=90% operating region.
    def att(model, dataset, sched, rate):
        for r in all_rows:
            if (r["model"], r["dataset"], r["sched"], r["rate"]) == \
                    (model, dataset, sched, rate):
                return r
        raise KeyError

    pareto_ok = all(
        att(m_, d_, "layered", r_)["slo"] >= att(m_, d_, "chunked", r_)["slo"]
        - 0.02
        for (m_, d_), rates in sweeps.items() for r_ in rates)

    def max_stable_rate(model, dataset, sched):
        best = 0.0
        for r_ in sweeps[(model, dataset)]:
            if att(model, dataset, sched, r_)["slo"] >= 0.90:
                best = max(best, r_)
        return best

    capacity = {}
    for (m_, d_) in sweeps:
        lay, chk = (max_stable_rate(m_, d_, "layered"),
                    max_stable_rate(m_, d_, "chunked"))
        capacity[f"{m_}/{d_}"] = {"layered": lay, "chunked": chk}
    cap_ok = all(v["layered"] >= v["chunked"] for v in capacity.values())
    cap_gain = any(v["layered"] > v["chunked"] for v in capacity.values())

    checks = {"layered_pareto_dominates": pareto_ok,
              "layered_capacity_geq": cap_ok,
              "layered_capacity_strictly_better_somewhere": cap_gain}
    print("\ncapacity (max rate with >=90% SLO):", capacity)
    print("checks:", checks)
    return {"rows": all_rows, "capacity": capacity, "checks": checks}


def run_oversubscribed(n_requests: int, sweeps) -> dict:
    """Memory-pressure points: pool ~3 residents, both preemption modes."""
    rows = []
    for (model, dataset), rates in sweeps.items():
        # the pressure behaviour changes with load, not with every rate
        # step — sample the sweep's endpoints plus the midpoint
        picked = sorted({rates[0], rates[len(rates) // 2], rates[-1]})
        for rate in picked:
            for sched in ("chunked", "layered"):
                for mode in PREEMPTION_MODES:
                    m, res = run_sim(model, dataset, sched, rate,
                                     n_requests=n_requests,
                                     oversubscribed=True,
                                     preemption_mode=mode)
                    rows.append({
                        "model": model, "dataset": dataset, "sched": sched,
                        "mode": mode, "rate": rate,
                        "slo": _finite(m["slo_attainment"]),
                        "queue_delay_mean": _finite(m["queue_delay_mean"]),
                        "queue_delay_p99": _finite(m["queue_delay_p99"]),
                        "preemption_rate": _finite(m["preemption_rate"]),
                        "swap_rate": _finite(m["swap_rate"]),
                        "swap_bytes": res.swap_bytes,
                        "swap_stall_time": res.swap_stall_time,
                        "restore_latency_mean":
                            _finite(m["restore_latency_mean"]),
                        "swap_dma_time": res.swap_dma_time,
                        "pages_high_water": res.pages_high_water,
                    })
    print(table(rows, ["model", "dataset", "sched", "mode", "rate", "slo",
                       "queue_delay_mean", "preemption_rate", "swap_rate",
                       "swap_bytes", "swap_dma_time", "swap_stall_time"],
                "Fig 3 (oversubscribed) — pool ~3 residents, "
                "recompute vs swap-to-host"))

    # Schema + behaviour checks: every row carries the full column set;
    # pressure really bit (somebody queued and somebody was evicted); swap
    # rows move bytes over the host link, recompute rows move none.
    schema_ok = all(all(c in r for c in OVERSUB_COLUMNS) for r in rows)
    pressured = any((r["preemption_rate"] or 0) > 0
                    or (r["swap_rate"] or 0) > 0 for r in rows)
    swap_traffic_ok = (
        all(r["swap_bytes"] == 0 for r in rows if r["mode"] == "recompute")
        and any(r["swap_bytes"] > 0 for r in rows if r["mode"] == "swap"))
    checks = {"oversub_schema": schema_ok,
              "oversub_pressure_bites": pressured,
              "oversub_swap_traffic": swap_traffic_ok}
    print("checks:", checks)
    return {"oversub_rows": rows, "oversub_columns": list(OVERSUB_COLUMNS),
            "checks": checks}


def run_spec_frontier(n_requests: int, sweeps, spec: str,
                      spec_acceptance: float) -> dict:
    """Chunked vs layered × speculation off/on at sampled rates.  The
    simulator's verify-k is analytic (seeded Bernoulli acceptance), so
    the frontier isolates the SCHEDULING effect of speculation: fewer,
    wider decode iterations at identical token streams."""
    rows = []
    for (model, dataset), rates in sweeps.items():
        picked = sorted({rates[0], rates[len(rates) // 2], rates[-1]})
        for rate in picked:
            for sched in ("chunked", "layered"):
                for sp in ("off", spec):
                    kw = {} if sp == "off" else dict(
                        spec_mode=sp, spec_k=4,
                        spec_acceptance=spec_acceptance)
                    m, res = run_sim(model, dataset, sched, rate,
                                     n_requests=n_requests, **kw)
                    rows.append({
                        "model": model, "dataset": dataset, "sched": sched,
                        "spec": sp, "rate": rate,
                        "slo": _finite(m["slo_attainment"]),
                        "ttft_att": _finite(m["ttft_attainment"]),
                        "tbt_att": _finite(m["tbt_attainment"]),
                        "acceptance_rate": _finite(res.acceptance_rate),
                        "n_iterations": res.n_iterations,
                        "total_generated": sum(r.n_generated
                                               for r in res.requests),
                    })
    print(table(rows, ["model", "dataset", "sched", "spec", "rate", "slo",
                       "ttft_att", "tbt_att", "acceptance_rate",
                       "n_iterations"],
                "Fig 3 (speculative) — chunked vs layered x verify-k "
                f"off/{spec}, analytic acceptance {spec_acceptance}"))

    def by(model, dataset, sched, rate, sp):
        for r in rows:
            if (r["model"], r["dataset"], r["sched"], r["rate"],
                    r["spec"]) == (model, dataset, sched, rate, sp):
                return r
        raise KeyError

    points = {(r["model"], r["dataset"], r["sched"], r["rate"])
              for r in rows}
    pairs = [(by(*p, "off"), by(*p, spec)) for p in sorted(points)]
    checks = {
        # speculation never changes WHAT is generated, only when
        "spec_frontier_token_invariant": all(
            off["total_generated"] == on["total_generated"]
            for off, on in pairs),
        # accepted drafts fold decode iterations together
        "spec_frontier_folds_iterations": all(
            on["n_iterations"] < off["n_iterations"]
            for off, on in pairs),
        "spec_frontier_engaged": all(
            (on["acceptance_rate"] or 0) > 0 for _, on in pairs),
        # folding iterations can only help the latency SLOs (epsilon for
        # attainment-quantization on small request counts)
        "spec_frontier_no_slo_loss": all(
            (on["slo"] or 0) >= (off["slo"] or 0) - 0.05
            for off, on in pairs),
    }
    print("checks:", checks)
    return {"spec_rows": rows, "spec_columns": list(SPEC_COLUMNS),
            "checks": checks}


def run_prefix_frontier(n_requests: int, models) -> dict:
    """Chunked vs layered × prefix cache off/on over a shared-prefix trace
    (4 system prompts, Zipf reuse).  With caching on, the cost model
    prices only the un-cached suffix of each warm prompt and layered
    admission starts its first layer-group rectangle past the cached
    block boundary — TTFT and expert traffic both drop, and the layered
    frontier is preserved on the warm path."""
    rows = []
    for model, rates in models.items():
        slo = SLOS[(model, "sharegpt")]
        for rate in rates:
            trace = shared_prefix_trace(
                n_requests, n_prefixes=4, prefix_len=PFX_PREFIX_LEN,
                suffix_len=PFX_SUFFIX_LEN, output_len=PFX_OUTPUT_LEN,
                rate=rate, zipf_alpha=1.2, vocab_size=50257, seed=1)
            for sched in ("chunked", "layered"):
                for cache_on in (False, True):
                    m, res, _ = run_sim_trace(model, trace, sched, slo=slo,
                                              prefix_cache=cache_on)
                    rows.append({
                        "model": model, "sched": sched,
                        "cache": "on" if cache_on else "off", "rate": rate,
                        "n_requests": m["n_requests"],
                        "ttft_mean": _finite(m["ttft_mean"]),
                        "ttft_p99": _finite(m["ttft_p99"]),
                        "slo": _finite(m["slo_attainment"]),
                        "hit_rate": m["prefix_hit_rate"],
                        "cached_tokens": res.prefix_cached_tokens,
                        "expert_bytes": m["expert_bytes_total"],
                        "n_iterations": res.n_iterations,
                    })
    print(table(rows, ["model", "sched", "cache", "rate", "ttft_mean",
                       "ttft_p99", "slo", "hit_rate", "cached_tokens",
                       "expert_bytes", "n_iterations"],
                "Fig 3 (prefix caching) — shared-prefix trace "
                f"({PFX_PREFIX_LEN}+{PFX_SUFFIX_LEN} tokens), chunked vs "
                "layered x cache off/on"))

    def by(model, sched, rate, cache):
        for r in rows:
            if (r["model"], r["sched"], r["rate"], r["cache"]) == \
                    (model, sched, rate, cache):
                return r
        raise KeyError

    points = sorted({(r["model"], r["sched"], r["rate"]) for r in rows})
    pairs = [(by(*p, "off"), by(*p, "on")) for p in points]
    checks = {
        # warm requests really hit (Zipf over 4 prefixes, ~86% reuse)
        "pfx_hit_on": all(on["hit_rate"] >= 0.3 for _, on in pairs),
        "pfx_off_cold": all(off["hit_rate"] == 0 for off, _ in pairs),
        # pricing only the suffix can only shorten prefill queues
        "pfx_ttft_improves": all(
            (on["ttft_mean"] or 0) <= (off["ttft_mean"] or float("inf"))
            for off, on in pairs),
        # cached prompt blocks never re-load expert weights
        "pfx_expert_bytes_drop": all(
            on["expert_bytes"] < off["expert_bytes"] for off, on in pairs),
        # the layered frontier survives the warm path
        "pfx_layered_frontier": all(
            (by(m_, "layered", r_, "on")["slo"] or 0)
            >= (by(m_, "chunked", r_, "on")["slo"] or 0) - 0.05
            for m_, _s, r_ in points if _s == "layered"),
    }
    print("checks:", checks)
    return {"pfx_rows": rows, "pfx_columns": list(PFX_COLUMNS),
            "checks": checks}


def run_disagg_frontier(n_requests: int, sweeps) -> dict:
    """Chunked vs layered × stream/whole KV handoff over the two-pool
    simulator.  Layered prefill completes each layer group's KV early, so
    group-granular streaming overlaps the link with the remaining groups'
    compute; whole-prompt handoff ships everything after the last group
    and eats the transfer as exposed stall.  Chunked prefill's final
    chunk covers every block, so its stream mode degenerates to whole —
    only the layered schedule can exploit the link overlap."""
    from repro.configs import get_config
    from repro.launch.config import ServeConfig
    from repro.serving.cost_model import H100X2
    from repro.serving.metrics import request_metrics
    from repro.serving.simulator import DisaggSimulator
    from repro.serving.traffic import poisson_trace
    rows = []
    for (model, dataset), rates in sweeps.items():
        cfg = get_config(model)
        slo = SLOS[(model, dataset)]
        base = ServeConfig(arch=model, simulate=True, slots=128,
                           token_budget=512, quantum=512).validate()
        for rate in rates:
            trace = poisson_trace(DATASETS[dataset], rate, n_requests,
                                  seed=0)
            for sched in ("chunked", "layered"):
                for handoff in ("stream", "whole"):
                    sim = DisaggSimulator(cfg, sched, H100X2,
                                          handoff=handoff,
                                          **base.sim_kwargs())
                    res = sim.run(trace)
                    m = request_metrics(res.requests, slo)
                    rows.append({
                        "model": model, "dataset": dataset, "sched": sched,
                        "handoff": handoff, "rate": rate,
                        "slo": _finite(m["slo_attainment"]),
                        "ttft_mean": _finite(m["ttft_mean"]),
                        "tbt_mean": _finite(m["tbt_mean"]),
                        "decode_tbt_mean":
                            _finite(res.decode_pool_tbt_mean),
                        "n_migrations": res.n_migrations,
                        "n_returns": res.n_returns,
                        "link_bytes": res.link_bytes,
                        "link_stall_time": res.link_stall_time,
                        "handoff_wait_time": res.handoff_wait_time,
                        "migration_queue_peak": res.migration_queue_peak,
                        "decode_prefill_slices": res.decode_prefill_slices,
                        "_finished": all(r.finish_time is not None
                                         for r in res.requests),
                    })
    print(table(rows, ["model", "dataset", "sched", "handoff", "rate",
                       "slo", "ttft_mean", "decode_tbt_mean",
                       "n_migrations", "link_bytes", "link_stall_time",
                       "migration_queue_peak"],
                "Fig 3 (disaggregated) — prefill/decode pools, "
                "group-granular streaming vs whole-prompt KV handoff"))

    def by(model, dataset, sched, rate, handoff):
        for r in rows:
            if (r["model"], r["dataset"], r["sched"], r["rate"],
                    r["handoff"]) == (model, dataset, sched, rate, handoff):
                return r
        raise KeyError

    points = sorted({(r["model"], r["dataset"], r["sched"], r["rate"])
                     for r in rows})
    pairs = [(by(*p, "stream"), by(*p, "whole")) for p in points]
    lay_pairs = [(s, w) for s, w in pairs if s["sched"] == "layered"]
    checks = {
        "disagg_schema": all(all(c in r for c in DISAGG_COLUMNS)
                             for r in rows),
        # the zero-prefill-stall gate: the decode pool's iteration clock
        # NEVER contains prefill work, so every decode-pool TBT sample is
        # prefill-free by construction
        "disagg_decode_prefill_free": all(
            r["decode_prefill_slices"] == 0 for r in rows),
        # streaming never exposes more link stall than whole-prompt...
        "disagg_stream_never_worse": all(
            s["link_stall_time"] <= w["link_stall_time"] + 1e-9
            for s, w in pairs),
        # ...and under the layered schedule it is STRICTLY better — the
        # overlap claim the disaggregation argument rests on (chunked
        # degenerates to whole, so it cannot separate)
        "disagg_stream_dominates_whole": all(
            s["link_stall_time"] < w["link_stall_time"]
            for s, w in lay_pairs) and bool(lay_pairs),
        "disagg_all_complete": all(r.pop("_finished") for r in rows),
        "disagg_every_request_crosses": all(
            r["n_migrations"] >= n_requests for r in rows),
    }
    print("checks:", checks)
    return {"disagg_rows": rows, "disagg_columns": list(DISAGG_COLUMNS),
            "checks": checks}


def _attach_class_prefixes(trace, prefix_len: int = 256,
                           vocab_size: int = 50257, seed: int = 0):
    """Give each SLO class a shared system prompt: every request longer
    than ``prefix_len`` carries its class prefix plus a fresh random tail
    (lengths unchanged), so the multi-tenant rows exercise per-class
    prefix caching instead of reporting all-zero hit rates."""
    rng = np.random.default_rng(seed)
    prefixes = {}
    out = []
    for tr in trace:
        pfx = prefixes.setdefault(
            tr.slo_class,
            tuple(int(x) for x in rng.integers(1, vocab_size, prefix_len)))
        n_fresh = max(tr.prompt_len - prefix_len, 0)
        toks = (pfx[:tr.prompt_len]
                + tuple(int(x) for x in
                        rng.integers(1, vocab_size, n_fresh)))
        out.append(TraceRequest(tr.arrival_time, tr.prompt_len,
                                tr.output_len, slo_class=tr.slo_class,
                                prompt_tokens=toks))
    return out


def _class_eviction_probe(mode: str) -> bool:
    """Deterministic 3-resident scenario proving the class-aware victim
    walk: interactive (earliest, protected by the forward-progress rule),
    batch, interactive (latest).  When decode growth overruns the pool,
    the BATCH resident must be the victim even though an interactive one
    arrived later — recency alone would evict request 2."""
    from repro.configs import get_config
    from repro.serving.cost_model import H100X2
    from repro.serving.simulator import Simulator
    from repro.serving.traffic import TraceRequest
    trace = [
        TraceRequest(0.0, 256, 16, slo_class="interactive"),
        TraceRequest(0.1, 256, 64, slo_class="batch"),
        TraceRequest(0.2, 256, 16, slo_class="interactive"),
    ]
    sim = Simulator(get_config("qwen3-30b-a3b"), "chunked", H100X2,
                    n_slots=8, token_budget=512, quantum=512,
                    n_pages=50, page_size=16, decode_reserve=0,
                    preemption_mode=mode)
    res = sim.run(trace)
    evicted = {r.req_id: r.n_preemptions + r.n_swaps for r in res.requests}
    return evicted[1] > 0 and evicted[0] == 0 and evicted[2] == 0


def run_multi_tenant(n_requests: int, models, spec_kw=None) -> dict:
    """Mixed interactive+batch trace under an oversubscribed pool, swept
    under BOTH preemption modes: emits one row per (model, sched, mode,
    rate, slo_class) with the per-class TTFT/TBT/attainment breakdown.
    ``spec_kw`` (spec_mode/spec_k/spec_acceptance) runs the points with
    verify-k speculation on and fills the per-class ``accept_rate``."""
    spec_kw = spec_kw or {}
    rows = []
    evictions = {"interactive": 0.0, "batch": 0.0}
    for model, rates in models.items():
        slos = {"interactive": SLOS[(model, "sharegpt")],
                "batch": SLOS[(model, "arxiv")]}
        for rate in rates:
            n_batch = max(1, int(round(n_requests * MT_BATCH_SHARE)))
            trace = _attach_class_prefixes(multi_class_trace([
                ClassSpec("interactive", DATASETS["sharegpt"],
                          rate * (1 - MT_BATCH_SHARE),
                          n_requests - n_batch),
                ClassSpec("batch", DATASETS["arxiv"],
                          rate * MT_BATCH_SHARE, n_batch,
                          process="bursty"),
            ]))
            for sched in ("chunked", "layered"):
                for mode in PREEMPTION_MODES:
                    m, res, per_cls = run_sim_trace(
                        model, trace, sched, slo=slos, oversubscribed=True,
                        preemption_mode=mode, **spec_kw)
                    for cls, cm in per_cls.items():
                        rows.append({
                            "model": model, "sched": sched, "mode": mode,
                            "rate": rate, "slo_class": cls,
                            "n_requests": cm["n_requests"],
                            "ttft_p50": _finite(cm["ttft_p50"]),
                            "ttft_p99": _finite(cm["ttft_p99"]),
                            "tbt_p50": _finite(cm["tbt_p50"]),
                            "tbt_p99": _finite(cm["tbt_p99"]),
                            "ttft_att": _finite(cm["ttft_attainment"]),
                            "tbt_att": _finite(cm["tbt_attainment"]),
                            "slo": _finite(cm["slo_attainment"]),
                            "queue_delay_p99":
                                _finite(cm["queue_delay_p99"]),
                            "preemption_rate":
                                _finite(cm["preemption_rate"]),
                            "swap_rate": _finite(cm["swap_rate"]),
                            "accept_rate":
                                _finite(cm["spec_acceptance_rate"]),
                            "hit_rate": cm["prefix_hit_rate"],
                            "cached_tokens":
                                _finite(cm["cached_prompt_tokens"]),
                        })
                        evictions[cls] += (cm["n_preemptions"]
                                           + cm["n_swaps"])
    print(table(rows, ["model", "sched", "mode", "rate", "slo_class",
                       "ttft_p50", "ttft_p99", "slo", "queue_delay_p99",
                       "preemption_rate", "swap_rate", "accept_rate",
                       "hit_rate"],
                "Fig 3 (multi-tenant) — interactive ShareGPT (Poisson) + "
                "batch arXiv (bursty), oversubscribed pool"))

    # Schema + behaviour checks: full column set; both classes present at
    # every operating point; and the class-aware victim walk demonstrably
    # evicts batch residents ahead of later-arriving interactive ones
    # (deterministic probe — the sweep's aggregate eviction counts are
    # workload-dependent: an arXiv batch request is often the protected
    # earliest resident or still queued when pressure hits, so they are
    # reported in the rows but not asserted on).
    schema_ok = all(all(c in r for c in MT_COLUMNS) for r in rows)
    points = {(r["model"], r["sched"], r["mode"], r["rate"]) for r in rows}
    classes_ok = all(
        {r["slo_class"] for r in rows
         if (r["model"], r["sched"], r["mode"], r["rate"]) == p}
        == {"interactive", "batch"} for p in points)
    probe_ok = all(_class_eviction_probe(m) for m in PREEMPTION_MODES)
    # every class shares a system prompt, so somebody must have hit
    hits_ok = any(r["hit_rate"] > 0 for r in rows)
    checks = {"mt_schema": schema_ok,
              "mt_both_classes": classes_ok,
              "mt_eviction_order_probe": probe_ok,
              "mt_prefix_hits": hits_ok}
    print("per-class evictions (preempt+swap):", evictions)
    print("checks:", checks)
    return {"mt_rows": rows, "mt_columns": list(MT_COLUMNS),
            "checks": checks}


def main(n_requests: int = 400, oversubscribed: bool = False,
         multi_tenant: bool = False, smoke: bool = False,
         spec: str = "off", spec_acceptance: float = 0.7,
         prefix: bool = False, disagg: bool = False) -> dict:
    sweeps = SWEEPS
    if smoke:
        # tiny CI-sized run: one model/dataset pair, two rates
        key = ("qwen3-30b-a3b", "sharegpt")
        sweeps = {key: SWEEPS[key][:2]}
        n_requests = min(n_requests, 24)
    result = run_unconstrained(n_requests, sweeps)
    if smoke:
        # a 24-request run at two pre-saturation rates cannot resolve a
        # capacity gap — both schedulers sit at 100% SLO attainment
        result["checks"].pop("layered_capacity_strictly_better_somewhere")
    if oversubscribed:
        over = run_oversubscribed(n_requests, sweeps)
        result["oversub_rows"] = over["oversub_rows"]
        result["oversub_columns"] = over["oversub_columns"]
        result["checks"].update(over["checks"])
    if spec != "off":
        sf = run_spec_frontier(n_requests, sweeps, spec, spec_acceptance)
        result["spec_rows"] = sf["spec_rows"]
        result["spec_columns"] = sf["spec_columns"]
        result["checks"].update(sf["checks"])
    if prefix:
        models = PFX_SWEEPS
        if smoke:
            key = "qwen3-30b-a3b"
            models = {key: PFX_SWEEPS[key][:1]}
        pf = run_prefix_frontier(n_requests, models)
        result["pfx_rows"] = pf["pfx_rows"]
        result["pfx_columns"] = pf["pfx_columns"]
        result["checks"].update(pf["checks"])
    if disagg:
        dsweeps = DISAGG_SWEEPS
        if smoke:
            key = ("qwen3-30b-a3b", "arxiv")
            dsweeps = {key: DISAGG_SWEEPS[key][:1]}
        dg = run_disagg_frontier(n_requests, dsweeps)
        result["disagg_rows"] = dg["disagg_rows"]
        result["disagg_columns"] = dg["disagg_columns"]
        result["checks"].update(dg["checks"])
    if multi_tenant:
        models = MT_SWEEPS
        if smoke:
            key = "qwen3-30b-a3b"
            models = {key: MT_SWEEPS[key][:1]}
        spec_kw = {} if spec == "off" else dict(
            spec_mode=spec, spec_k=4, spec_acceptance=spec_acceptance)
        mt = run_multi_tenant(n_requests, models, spec_kw=spec_kw)
        result["mt_rows"] = mt["mt_rows"]
        result["mt_columns"] = mt["mt_columns"]
        result["checks"].update(mt["checks"])
    result["pass"] = all(result["checks"].values())
    save("fig3_slo_attainment", result)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--oversubscribed", action="store_true",
                    help="add memory-pressure points (pool ~3 residents) "
                         "sweeping both preemption modes")
    ap.add_argument("--multi-tenant", action="store_true",
                    help="add mixed-class points (interactive ShareGPT + "
                         "bursty batch arXiv, oversubscribed pool) with "
                         "per-class TTFT/TBT/attainment rows")
    ap.add_argument("--spec", choices=["off", "ngram", "draft"],
                    default="off",
                    help="add the speculative verify-k frontier (chunked "
                         "vs layered x spec off/on, analytic acceptance); "
                         "also speculates the --multi-tenant points")
    ap.add_argument("--spec-acceptance", type=float, default=0.7,
                    help="per-token draft acceptance probability for the "
                         "simulator's analytic verify-k")
    ap.add_argument("--prefix", action="store_true",
                    help="add the prefix-caching frontier (chunked vs "
                         "layered x cache off/on over a shared-prefix "
                         "trace) with TTFT/hit-rate/expert-traffic rows")
    ap.add_argument("--disagg", action="store_true",
                    help="add the disaggregated-serving frontier (chunked "
                         "vs layered x stream/whole KV handoff between "
                         "the prefill and decode pools) with link-stall "
                         "and decode-pool TBT rows")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (one sweep, <=24 requests)")
    args = ap.parse_args()
    main(n_requests=args.requests, oversubscribed=args.oversubscribed,
         multi_tenant=args.multi_tenant, smoke=args.smoke,
         spec=args.spec, spec_acceptance=args.spec_acceptance,
         prefix=args.prefix, disagg=args.disagg)
