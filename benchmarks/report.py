"""Emit the EXPERIMENTS.md §Dry-run and §Roofline tables in markdown from
the dry-run artifacts. Run after launch/dryrun --all:

  PYTHONPATH=src:. python -m benchmarks.report > experiments/roofline.md
"""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load(mesh):
    out = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR,
                                              f"*_{mesh}.json"))):
        with open(path) as f:
            d = json.load(f)
        out[(d["arch"], d["shape"])] = d
    return out


def fmt_t(x):
    if x is None:
        return "-"
    if x >= 0.01:
        return f"{x:.3f}"
    return f"{x:.2e}"


def main() -> None:
    single = load("16x16")
    multi = load("2x16x16")

    print("### Dry-run matrix (status x mesh)\n")
    print("| arch | shape | 16x16 | 2x16x16 | mem/dev 16x16 (GB) |"
          " mem/dev 2x16x16 (GB) |")
    print("|---|---|---|---|---|---|")
    for key in sorted(single):
        s, m = single[key], multi.get(key, {})
        ms = s.get("peak_memory_per_device")
        mm = m.get("peak_memory_per_device")
        print(f"| {key[0]} | {key[1]} | {s['status']} | "
              f"{m.get('status', '?')} | "
              f"{ms / 1e9:.2f} | " if ms else
              f"| {key[0]} | {key[1]} | {s['status']} | "
              f"{m.get('status', '?')} | - | ", end="")
        print(f"{mm / 1e9:.2f} |" if mm else "- |")

    print("\n### Roofline terms (single pod, 256 chips, per device)\n")
    print("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) |"
          " bottleneck | MODEL/HLO flops | mem/dev GB |")
    print("|---|---|---|---|---|---|---|---|")
    for key in sorted(single):
        d = single[key]
        if d.get("status") == "skipped":
            print(f"| {key[0]} | {key[1]} | — | — | — | skipped "
                  f"(sub-quadratic gate) | — | — |")
            continue
        if "t_compute_s" not in d:
            continue
        mem = d.get("peak_memory_per_device")
        print(f"| {key[0]} | {key[1]} | {fmt_t(d['t_compute_s'])} | "
              f"{fmt_t(d['t_memory_s'])} | {fmt_t(d['t_collective_s'])} | "
              f"{d['bottleneck']} | {d['useful_flops_ratio']:.2f} | "
              f"{mem / 1e9:.1f} |" if mem else "- |")

    print("\n### Collective mix (single pod; bytes/device by kind)\n")
    print("| arch | shape | all-gather | all-reduce | reduce-scatter |"
          " all-to-all | permute |")
    print("|---|---|---|---|---|---|---|")
    for key in sorted(single):
        d = single[key]
        cb = d.get("collective_by_kind")
        if not cb:
            continue
        def g(k):
            v = cb.get(k, 0)
            return f"{v / 1e9:.2f}G" if v else "0"
        print(f"| {key[0]} | {key[1]} | {g('all-gather')} | "
              f"{g('all-reduce')} | {g('reduce-scatter')} | "
              f"{g('all-to-all')} | {g('collective-permute')} |")


if __name__ == "__main__":
    main()
