"""Paper Table 1: expert-weight coverage ratio vs decode batch size.

Two measurements:
  (a) the REAL router of a reduced Qwen3-family MoE model, averaged over
      random decode batches — the mechanism measurement;
  (b) the calibrated analytic coverage model at the paper's scale
      (128 experts, top-8) against the paper's measured percentages.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import save, table
from repro.configs import get_smoke_config
from repro.models import moe
from repro.serving.cost_model import expected_coverage

PAPER_TABLE1 = {1: 6.25, 2: 11.7, 4: 21.3, 8: 29.0, 16: 44.5, 32: 54.7,
                64: 69.4, 128: 86.3, 256: 93.4, 512: 98.0}


def real_router_coverage(batches=(1, 2, 4, 8, 16), n_trials=8):
    """Coverage measured from the reduced model's actual router."""
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    e = cfg.moe
    rows = []
    for b in batches:
        covs = []
        for t in range(n_trials):
            x = jax.random.normal(jax.random.PRNGKey(100 + t),
                                  (b, cfg.d_model))
            idx, _, _ = moe.route(cfg, p, x)
            covs.append(len(np.unique(np.asarray(idx))) / e.n_experts)
        rows.append({"batch": b, "coverage_pct": 100 * float(np.mean(covs)),
                     "uniform_pct": 100 * expected_coverage(
                         e.n_experts, e.top_k, b, alpha=1.0) / e.n_experts})
    return rows


def main() -> dict:
    model_rows = []
    for b, pct in PAPER_TABLE1.items():
        got = expected_coverage(128, 8, b) / 128 * 100
        model_rows.append({"batch": b, "paper_pct": pct,
                           "model_pct": round(got, 2),
                           "rel_err": round(abs(got - pct) / pct, 3)})
    real_rows = real_router_coverage()
    print(table(model_rows, ["batch", "paper_pct", "model_pct", "rel_err"],
                "Table 1 — coverage model (128e top-8) vs paper"))
    print()
    print(table(real_rows, ["batch", "coverage_pct", "uniform_pct"],
                "Real-router coverage (reduced qwen3-moe, 4e top-2)"))
    worst = max(r["rel_err"] for r in model_rows)
    result = {"model_vs_paper": model_rows, "real_router": real_rows,
              "worst_rel_err": worst, "pass": worst < 0.20}
    save("table1_coverage", result)
    return result


if __name__ == "__main__":
    main()
