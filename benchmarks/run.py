"""Benchmark driver: one entry per paper table/figure plus the roofline
aggregation. ``python -m benchmarks.run [--fast]`` runs everything and
prints a pass/fail summary (results land in experiments/results/)."""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (fig2_chunk_microbench, fig3_slo_attainment,
                        fig5_tokens_over_time, gmm_ragged_vs_dense, roofline,
                        table1_coverage, table2_chunk_tradeoff,
                        table6_latency, table7_expert_loads, table8_energy)

BENCHES = [
    ("table1_coverage", table1_coverage.main, {}),
    ("fig2_chunk_microbench", fig2_chunk_microbench.main, {}),
    ("gmm_ragged_vs_dense", gmm_ragged_vs_dense.main, {}),
    ("table2_chunk_tradeoff", table2_chunk_tradeoff.main, {}),
    ("fig3_slo_attainment", fig3_slo_attainment.main, {"fast_kw": "n_requests"}),
    ("table6_latency", table6_latency.main, {"fast_kw": "n_requests"}),
    ("table7_expert_loads", table7_expert_loads.main, {"fast_kw": "n_requests"}),
    ("fig5_tokens_over_time", fig5_tokens_over_time.main, {"fast_kw": "n_requests"}),
    ("table8_energy", table8_energy.main, {"fast_kw": "n_requests"}),
    ("roofline", roofline.main, {}),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller traces (CI-speed)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    summary = []
    for name, fn, meta in BENCHES:
        if args.only and args.only not in name:
            continue
        kw = {}
        if args.fast and meta.get("fast_kw"):
            kw[meta["fast_kw"]] = 60
        print(f"\n{'=' * 72}\n{name}\n{'=' * 72}")
        t0 = time.time()
        res = fn(**kw)
        summary.append((name, res.get("pass", None), time.time() - t0))

    print(f"\n{'=' * 72}\nSUMMARY\n{'=' * 72}")
    failed = []
    for name, ok, dt in summary:
        status = {True: "PASS", False: "FAIL", None: "-"}[ok]
        print(f"  {name:<28} {status:<6} {dt:6.1f}s")
        if ok is False:
            failed.append(name)
    if failed:
        sys.exit(f"benchmark validation failures: {failed}")
    print("\nall paper-validation checks passed")


if __name__ == "__main__":
    main()
