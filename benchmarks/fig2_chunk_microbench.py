"""Paper Figure 2: MoE weight loading and kernel runtime vs prefill chunk
size, input fixed at 8192 tokens (Qwen3-30B-A3B on the paper's 2xH100
testbed model).

Paper claims validated:
  - chunk 512: MoE runtime > 50% of prefill runtime, prefill > 500 ms;
  - load falls ~1/chunk-size;
  - by 4096-8192: MoE load < 100 GB and prefill runtime stabilizes ~200 ms.
"""

from __future__ import annotations

from benchmarks.common import save, table
from repro.configs import get_config
from repro.core.plan import IterationPlan, PrefillSlice
from repro.serving.cost_model import H100X2, CostModel

INPUT_LEN = 8192
CHUNKS = (512, 1024, 2048, 4096, 8192)


def prefill_cost(cfg, chunk_size: int):
    cm = CostModel(cfg, H100X2)
    L = cfg.n_layers
    total = {"duration": 0.0, "expert_bytes": 0.0, "hbm_bytes": 0.0,
             "flops": 0.0, "moe_time": 0.0, "other_time": 0.0}
    n_chunks = INPUT_LEN // chunk_size
    for i in range(n_chunks):
        sl = PrefillSlice(0, i * chunk_size, (i + 1) * chunk_size, 0, L,
                          emits_first_token=(i == n_chunks - 1))
        cost = cm.iteration_cost(IterationPlan(prefill=[sl]), {})
        total["duration"] += cost["duration"]
        total["expert_bytes"] += cost["expert_bytes"]
        total["hbm_bytes"] += cost["hbm_bytes"]
        total["flops"] += cost["flops"]
        # split: MoE expert streaming time vs everything else
        moe_t = cost["expert_bytes"] / cm.hw.hbm_bw
        total["moe_time"] += moe_t
        total["other_time"] += cost["duration"] - moe_t
    return total


def main() -> dict:
    cfg = get_config("qwen3-30b-a3b")
    rows = []
    for c in CHUNKS:
        t = prefill_cost(cfg, c)
        rows.append({
            "chunk": c,
            "n_chunks": INPUT_LEN // c,
            "moe_load_gb": t["expert_bytes"] / 1e9,
            "prefill_ms": t["duration"] * 1e3,
            "moe_frac": t["moe_time"] / t["duration"],
        })
    print(table(rows, ["chunk", "n_chunks", "moe_load_gb", "prefill_ms",
                       "moe_frac"],
                f"Fig 2 — MoE load & runtime vs chunk size ({INPUT_LEN}-tok "
                "input, Qwen3-30B-A3B, 2xH100 model)"))
    by = {r["chunk"]: r for r in rows}
    checks = {
        "chunk512_moe_dominant": by[512]["moe_frac"] > 0.5,
        "chunk512_prefill_over_500ms": by[512]["prefill_ms"] > 500,
        "load_roughly_inverse": 1.6 < by[512]["moe_load_gb"]
        / by[1024]["moe_load_gb"] < 2.2,
        "chunk8192_load_under_100gb": by[8192]["moe_load_gb"] < 100,
        "large_chunk_runtime_stabilizes":
            by[8192]["prefill_ms"] < 0.55 * by[512]["prefill_ms"],
    }
    print("\nchecks:", checks)
    result = {"rows": rows, "checks": checks,
              "pass": all(checks.values())}
    save("fig2_chunk_microbench", result)
    return result


if __name__ == "__main__":
    main()
