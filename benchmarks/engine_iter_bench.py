"""Microbench: the engine iteration hot path — packed layer-group batches
vs per-slice dispatch (DESIGN.md §Engine hot path).

For chunked vs layered scheduling x packed vs per-slice execution on two
tiny real-model configs (dense and MoE), a burst of co-resident requests
is drained twice through the SAME engine: the first pass compiles every
executable, the second pass is measured — wall-clock per iteration,
engine-level device launches (jit dispatches), prefill executables
compiled, and peak live device buffers (donation keeps the KV pool from
being duplicated per call).

Emits a strict-JSON result in the BENCH-trajectory schema
(``schema: "bench-trajectory-v1"`` — rows + columns + checks) so future
PRs can track the perf curve; CI's bench-smoke lane runs ``--smoke`` and
fails if the packed path ever dispatches more executables than the
per-slice path.
"""

from __future__ import annotations

import argparse
import gc

import jax
import numpy as np

from benchmarks.common import Timer, save, table
from repro.core.base import make_scheduler
from repro.models.config import ModelConfig, MoEConfig
from repro.models.model import DecoderModel
from repro.serving.engine import Engine

N_SLOTS = 8
MAX_LEN = 256

COLUMNS = ["config", "scheduler", "packed", "n_requests", "n_iterations",
           "wall_s", "ms_per_iter", "n_dispatches", "dispatches_per_iter",
           "prefill_dispatches", "prefill_compiles", "peak_live_mb",
           "cohort_prefills"]

# best-of-N measured drains: single-drain wall times on CPU are noise
# dominated (a drain is ~5-10 iterations of a tiny model)
MEASURE_REPEATS = 3


def _cfg_dense(smoke: bool) -> ModelConfig:
    return ModelConfig(
        name="bench-dense-4l", family="dense", n_layers=2 if smoke else 4,
        d_model=64 if smoke else 128, n_heads=4, n_kv_heads=2,
        d_ff=128 if smoke else 256, vocab_size=256,
        max_seq_len=MAX_LEN).validate()


def _cfg_moe(smoke: bool) -> ModelConfig:
    return ModelConfig(
        name="bench-moe-2l", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
        max_seq_len=MAX_LEN,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=64)).validate()


def _jobs(smoke: bool, seed: int = 0):
    """A burst of co-resident requests with mixed prompt shapes: layered
    merges them into one >=4-wide cohort (the regime where packing wins),
    chunked interleaves their chunks."""
    rng = np.random.default_rng(seed)
    n = 4 if smoke else 6
    lens = rng.integers(12, 28 if smoke else 56, n)
    return [(list(rng.integers(1, 200, int(ln))), 4 if smoke else 6)
            for ln in lens]


def run_one(cfg: ModelConfig, sched_name: str, packed: bool, jobs) -> dict:
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def build():
        sched = make_scheduler(sched_name, model.n_blocks, n_slots=N_SLOTS,
                               quantum=8, token_budget=32)
        return Engine(model, params, sched, n_slots=N_SLOTS,
                      max_len=MAX_LEN, packed=packed)

    def drain(eng, measure: bool):
        for prompt, max_new in jobs:
            eng.submit(prompt, max_new)
        iters, peak, widest = 0, 0.0, 0
        d0 = eng.n_dispatches
        with Timer() as t:
            while eng.scheduler.has_work():
                plan = eng.step()
                iters += 1
                widest = max(widest, len(plan.prefill))
                if measure:
                    peak = max(peak, sum(a.nbytes
                                         for a in jax.live_arrays()) / 1e6)
        return iters, t.elapsed, eng.n_dispatches - d0, peak, widest

    # pass 1 compiles every executable (same engine => same jit caches);
    # the measured passes are steady state — best of MEASURE_REPEATS
    eng = build()
    drain(eng, measure=False)
    outputs_warm = {r: list(v) for r, v in eng.outputs.items()}
    compiles = eng.n_prefill_compiles
    wall, peak = float("inf"), 0.0
    for _ in range(MEASURE_REPEATS):
        # engines hold reference cycles (jit partials -> self); collect so
        # a previous run's dead cache cannot inflate this run's live bytes
        gc.collect()
        pre0 = eng.n_prefill_dispatches
        iters, w, dispatches, pk, cohort = drain(eng, measure=True)
        wall = min(wall, w)
        peak = max(peak, pk)
        prefill_dispatches = eng.n_prefill_dispatches - pre0
    return {
        "config": cfg.name, "scheduler": sched_name, "packed": packed,
        "n_requests": len(jobs), "n_iterations": iters,
        "wall_s": wall, "ms_per_iter": wall / max(iters, 1) * 1e3,
        "n_dispatches": dispatches,
        "dispatches_per_iter": dispatches / max(iters, 1),
        "prefill_dispatches": prefill_dispatches,
        "prefill_compiles": compiles,
        "peak_live_mb": peak,
        "cohort_prefills": cohort,
        "_outputs": {int(r): v for r, v in outputs_warm.items()},
        "_outputs2": {int(r): list(v) for r, v in eng.outputs.items()},
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: one dense config, smaller burst")
    args = ap.parse_args(argv)

    cfgs = [_cfg_dense(args.smoke)]
    if not args.smoke:
        cfgs.append(_cfg_moe(args.smoke))
    jobs = _jobs(args.smoke)

    rows = []
    for cfg in cfgs:
        for sched in ("chunked", "layered"):
            for packed in (False, True):
                rows.append(run_one(cfg, sched, packed, jobs))

    def pair(cfg_name, sched):
        ps = next(r for r in rows if r["config"] == cfg_name
                  and r["scheduler"] == sched and not r["packed"])
        pk = next(r for r in rows if r["config"] == cfg_name
                  and r["scheduler"] == sched and r["packed"])
        return ps, pk

    pairs = [pair(c.name, s) for c in cfgs for s in ("chunked", "layered")]
    checks = {
        # CI gate: packing must never dispatch MORE executables
        "packed_never_more_dispatches": all(
            pk["dispatches_per_iter"] <= ps["dispatches_per_iter"] + 1e-9
            for ps, pk in pairs),
        # the acceptance bar: >= 2x fewer dispatches per iteration for the
        # layered cohorts at >= 4 co-resident prefills
        "packed_2x_fewer_dispatches_layered": all(
            pk["n_dispatches"] * 2 <= ps["n_dispatches"]
            for ps, pk in pairs if pk["scheduler"] == "layered"
            and pk["cohort_prefills"] >= 4),
        "layered_cohort_at_least_4": any(
            pk["cohort_prefills"] >= 4 for _, pk in pairs
            if pk["scheduler"] == "layered"),
        # cohorts compile one executable per group; per-slice compiles one
        # per (group, P-bucket).  Chunked is excluded: its B=2 emit pairs
        # are shapes the per-slice path never traces at all.
        "packed_compiles_no_more_executables_layered": all(
            pk["prefill_compiles"] <= ps["prefill_compiles"]
            for ps, pk in pairs if pk["scheduler"] == "layered"),
        # bit-identical generation on both passes of every run
        "tokens_identical_packed_vs_slice": all(
            pk["_outputs"] == ps["_outputs"]
            and pk["_outputs2"] == ps["_outputs2"]
            for ps, pk in pairs),
        # donated cache buffers: the packed path must not hold materially
        # more live device memory than per-slice (the packed stash is one
        # batch instead of B rows; headroom covers allocator slack)
        "donation_bounds_live_bytes": all(
            pk["peak_live_mb"] <= ps["peak_live_mb"] * 1.25 + 1.0
            for ps, pk in pairs),
    }
    # wall-clock is CPU-noisy: tracked as a soft (non-gating) trajectory
    # signal with headroom; the JSON keeps the raw numbers per PR
    soft_checks = {
        "packed_wall_no_worse": all(
            pk["ms_per_iter"] <= ps["ms_per_iter"] * 1.10
            for ps, pk in pairs),
    }

    for r in rows:
        r.pop("_outputs"), r.pop("_outputs2")
    print(table(rows, COLUMNS, "Engine iteration hot path — packed "
                               "layer-group batches vs per-slice"))
    print("\nchecks:", checks)
    print("soft checks (non-gating):", soft_checks)
    res = {
        "schema": "bench-trajectory-v1",
        "bench": "engine_iter_bench",
        "smoke": args.smoke,
        "columns": COLUMNS,
        "rows": rows,
        "checks": checks,
        "soft_checks": soft_checks,
        "pass": all(checks.values()),
    }
    save("engine_iter_bench", res)
    return res


if __name__ == "__main__":
    main()
