"""Microbench: the engine iteration hot path — packed layer-group batches
vs per-slice dispatch (DESIGN.md §Engine hot path).

For chunked vs layered scheduling x packed vs per-slice execution on two
tiny real-model configs (dense and MoE), a burst of co-resident requests
is drained twice through the SAME engine: the first pass compiles every
executable, the second pass is measured — wall-clock per iteration,
engine-level device launches (jit dispatches), prefill executables
compiled, and peak live device buffers (donation keeps the KV pool from
being duplicated per call).

Schema v2 adds the DECODE path: speculative verify-k rows (spec off vs
on, two traces) with generated tokens per device dispatch, mean accepted
prefix length, and verify-executable compile counts — plus a cross-check
that the cost model's acceptance-adjusted expert-load prediction tracks
the engine's real ``iter_log`` expert-byte counters.

Schema v3 adds the PREFIX-CACHE path: open-loop replays of a
shared-prefix trace (Zipf reuse over a handful of system prompts) and a
zero-reuse control, cache on vs off, under both preemption flavours —
reporting TTFT, prefill dispatches saved, iter_log expert-load bytes,
and the token-weighted hit rate, plus a hit-aware cost-model cross-check
(the model prices only the uncached prefill rectangles, same commit path
as the fig3 sweeps).  All v1/v2 fields are kept unchanged.

Emits a strict-JSON result in the BENCH-trajectory schema
(``schema: "bench-trajectory-v3"`` — rows + columns + checks) so future
PRs can track the perf curve; CI's bench-smoke lane runs
``--smoke --spec ngram`` and fails if the packed path ever dispatches
more executables than the per-slice path, if speculation stops
amortizing dispatches on the lookahead-friendly trace, if the
shared-prefix trace stops hitting the cache, or if caching costs ANY
extra prefill dispatch on the zero-reuse control.
"""

from __future__ import annotations

import argparse
import copy
import gc

import jax
import numpy as np

from benchmarks.common import Timer, save, table
from repro.core.base import make_scheduler
from repro.models.config import ModelConfig, MoEConfig
from repro.models.model import DecoderModel
from repro.serving.cost_model import H100X2, CostModel
from repro.serving.engine import Engine
from repro.serving.metrics import request_metrics
from repro.serving.runtime import EngineExecutor, ServingRuntime
from repro.serving.traffic import attach_prompt_tokens, shared_prefix_trace

N_SLOTS = 8
MAX_LEN = 256

COLUMNS = ["config", "scheduler", "packed", "n_requests", "n_iterations",
           "wall_s", "ms_per_iter", "n_dispatches", "dispatches_per_iter",
           "prefill_dispatches", "prefill_compiles", "peak_live_mb",
           "cohort_prefills"]

SPEC_COLUMNS = ["config", "trace", "spec", "n_iterations", "gen_tokens",
                "n_dispatches", "tokens_per_dispatch", "iters_per_token",
                "mean_accepted_len", "acceptance_rate",
                "verify_dispatches", "verify_compiles"]

# best-of-N measured drains: single-drain wall times on CPU are noise
# dominated (a drain is ~5-10 iterations of a tiny model)
MEASURE_REPEATS = 3


def _cfg_dense(smoke: bool) -> ModelConfig:
    return ModelConfig(
        name="bench-dense-4l", family="dense", n_layers=2 if smoke else 4,
        d_model=64 if smoke else 128, n_heads=4, n_kv_heads=2,
        d_ff=128 if smoke else 256, vocab_size=256,
        max_seq_len=MAX_LEN).validate()


def _cfg_moe(smoke: bool) -> ModelConfig:
    return ModelConfig(
        name="bench-moe-2l", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
        max_seq_len=MAX_LEN,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=64)).validate()


def _jobs(smoke: bool, seed: int = 0):
    """A burst of co-resident requests with mixed prompt shapes: layered
    merges them into one >=4-wide cohort (the regime where packing wins),
    chunked interleaves their chunks."""
    rng = np.random.default_rng(seed)
    n = 4 if smoke else 6
    lens = rng.integers(12, 28 if smoke else 56, n)
    return [(list(rng.integers(1, 200, int(ln))), 4 if smoke else 6)
            for ln in lens]


def run_one(cfg: ModelConfig, sched_name: str, packed: bool, jobs) -> dict:
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def build():
        sched = make_scheduler(sched_name, model.n_blocks, n_slots=N_SLOTS,
                               quantum=8, token_budget=32)
        return Engine(model, params, sched, n_slots=N_SLOTS,
                      max_len=MAX_LEN, packed=packed)

    def drain(eng, measure: bool):
        for prompt, max_new in jobs:
            eng.submit(prompt, max_new)
        iters, peak, widest = 0, 0.0, 0
        d0 = eng.n_dispatches
        with Timer() as t:
            while eng.scheduler.has_work():
                plan = eng.step()
                iters += 1
                widest = max(widest, len(plan.prefill))
                if measure:
                    peak = max(peak, sum(a.nbytes
                                         for a in jax.live_arrays()) / 1e6)
        return iters, t.elapsed, eng.n_dispatches - d0, peak, widest

    # pass 1 compiles every executable (same engine => same jit caches);
    # the measured passes are steady state — best of MEASURE_REPEATS
    eng = build()
    drain(eng, measure=False)
    outputs_warm = {r: list(v) for r, v in eng.outputs.items()}
    compiles = eng.n_prefill_compiles
    wall, peak = float("inf"), 0.0
    for _ in range(MEASURE_REPEATS):
        # engines hold reference cycles (jit partials -> self); collect so
        # a previous run's dead cache cannot inflate this run's live bytes
        gc.collect()
        pre0 = eng.n_prefill_dispatches
        iters, w, dispatches, pk, cohort = drain(eng, measure=True)
        wall = min(wall, w)
        peak = max(peak, pk)
        prefill_dispatches = eng.n_prefill_dispatches - pre0
    return {
        "config": cfg.name, "scheduler": sched_name, "packed": packed,
        "n_requests": len(jobs), "n_iterations": iters,
        "wall_s": wall, "ms_per_iter": wall / max(iters, 1) * 1e3,
        "n_dispatches": dispatches,
        "dispatches_per_iter": dispatches / max(iters, 1),
        "prefill_dispatches": prefill_dispatches,
        "prefill_compiles": compiles,
        "peak_live_mb": peak,
        "cohort_prefills": cohort,
        "_outputs": {int(r): v for r, v in outputs_warm.items()},
        "_outputs2": {int(r): list(v) for r, v in eng.outputs.items()},
    }


# ------------------------------------------------------------ decode path

def _decode_jobs(kind: str, smoke: bool, seed: int = 0):
    """Two decode traces for the verify-k rows.  "repetitive" is
    lookahead-friendly: periodic-suffix prompts whose greedy continuations
    fall into the same cycle, so the n-gram drafter's acceptance is near 1.
    "adversarial" is repetition-free (sampled without replacement), so
    almost every proposal is rejected — the floor the TBT gate holds.

    The repetitive prompts are chosen so the seed-0 bench model's greedy
    streams stay periodic for the whole generation (constant or period-2
    attractors) — the regime prompt-lookup decoding targets.  Decode
    length is fixed at 32 in smoke too: the n-gram path needs a few
    rounds to lock onto the GENERATED stream's cycle, so short drains
    understate the steady-state amortization."""
    if kind == "repetitive":
        prompts = [[9] * 15, [1, 2, 3] * 5, [11] * 12]
    else:
        rng = np.random.default_rng(seed)
        prompts = [[int(t) + 1 for t in rng.choice(200, size=ln,
                                                   replace=False)]
                   for ln in (15, 12, 16)]
    del smoke
    return [(list(p), 32) for p in prompts]


def _build_spec_engine(cfg: ModelConfig, spec: str, model=None, params=None):
    model = model or DecoderModel(cfg)
    params = params if params is not None else model.init(
        jax.random.PRNGKey(0))
    sched = make_scheduler("layered", model.n_blocks, n_slots=N_SLOTS,
                           quantum=8, token_budget=32)
    kw = {}
    if spec != "off":
        kw = dict(spec_mode=spec, spec_k=4)
        if spec == "draft":
            # self-draft: the target model is its own drafter (the
            # all-accept path — bench exercises the dispatch shape, the
            # equivalence suite owns the rejection semantics)
            kw.update(draft_model=model, draft_params=params)
    return Engine(model, params, sched, n_slots=N_SLOTS, max_len=MAX_LEN,
                  packed=True, **kw), model, params


def run_decode(cfg: ModelConfig, spec: str, trace: str, jobs) -> dict:
    """Drain one decode-heavy burst and report the speculation economics:
    generated tokens per device dispatch (the amortization headline),
    iterations per token for the slowest request (the iteration-clock TBT
    proxy — speculation can only fold iterations, never add them), and
    the acceptance statistics."""
    eng, _, _ = _build_spec_engine(cfg, spec)
    for prompt, max_new in jobs:
        eng.submit(prompt, max_new)
    with Timer() as t:
        while eng.scheduler.has_work():
            eng.step()
    gen = sum(len(v) for v in eng.outputs.values())
    slowest = max(len(v) for v in eng.outputs.values())
    acc_lens = [a for r in eng.requests.values() for a in r.accepted_lens]
    return {
        "config": cfg.name, "trace": trace, "spec": spec,
        "n_iterations": eng.iteration, "gen_tokens": gen,
        "n_dispatches": eng.n_dispatches,
        "tokens_per_dispatch": gen / max(eng.n_dispatches, 1),
        "iters_per_token": eng.iteration / max(slowest, 1),
        "mean_accepted_len": (sum(acc_lens) / len(acc_lens)
                              if acc_lens else 0.0),
        "acceptance_rate": (eng.n_spec_accepted
                            / max(eng.n_spec_proposed, 1)),
        "verify_dispatches": eng.n_verify_dispatches,
        "verify_compiles": eng.n_verify_compiles,
        "wall_s": t.elapsed,
        "_outputs": {int(r): list(v) for r, v in eng.outputs.items()},
    }


def run_cost_check(smoke: bool, spec: str) -> dict:
    """Acceptance-adjusted cost model vs the real engine: replay a MoE
    burst with speculation on, price every EXECUTED plan (verify_len
    substituted with the engine's per-iteration executed window,
    request state snapshotted at plan time — the simulator's convention)
    and compare summed predicted expert-bytes against the engine's
    ``iter_log`` expert-load counters.  The model's coverage term is a
    probabilistic expectation over routers, so the band is generous; on
    these shapes both sides saturate coverage and land near 1.0."""
    cfg = _cfg_moe(smoke)
    eng, _, _ = _build_spec_engine(cfg, spec)
    # the engine counter measures bytes at the REAL parameter dtype —
    # price at the same width or the comparison is off by bf16/f32
    bp = eng._expert_bytes // max(cfg.expert_bytes(1), 1)
    cm = CostModel(cfg, H100X2, bytes_per_param=bp, moe_dispatch="ragged")
    for prompt, max_new in _decode_jobs("repetitive", smoke):
        eng.submit(prompt, max_new)
    predicted = 0.0
    while eng.scheduler.has_work():
        plan = eng.scheduler.next_plan(now=float(eng.iteration))
        snap = {r: copy.copy(eng.requests[r]) for r in plan.decode_ids}
        eng.execute_plan(plan)
        # price what actually ran: accepted windows shrink to k_eff, and
        # spec-skipped rows fall back to plain decode (verify_len 0)
        plan.verify_len = dict(eng.last_verify_executed)
        predicted += cm.iteration_cost(plan, snap)["expert_bytes"]
    measured = float(sum(row["expert_load_bytes"] for row in eng.iter_log))
    ratio = predicted / max(measured, 1.0)
    return {"config": cfg.name, "spec": spec,
            "predicted_expert_mb": predicted / 1e6,
            "measured_expert_mb": measured / 1e6,
            "ratio": ratio}


# ------------------------------------------------------------ prefix cache

PREFIX_COLUMNS = ["config", "trace", "mode", "prefix_cache", "n_requests",
                  "n_iterations", "ttft_mean", "prefill_tokens",
                  "prefill_dispatches", "dispatches_saved", "expert_load_mb",
                  "prefix_hit_rate", "cached_tokens", "n_preempted",
                  "n_swapped_out"]

PFX_PAGE = 16                  # KV page size for the prefix-cache rows


def _cfg_moe_wide() -> ModelConfig:
    """4-layer top-1-of-16 MoE: coverage stays token-count sensitive at
    bench scale (16 experts, 1 routed draw per token), so skipping the
    cached prefix tokens visibly cuts expert-load bytes — the regime the
    paper's layered-prefill expert accounting cares about.  Four blocks
    also give the layered scheduler a real group count to shrink: a cold
    120-token prompt at quantum 16 prefills over 4 iterations, a cached
    one over 1."""
    return ModelConfig(
        name="bench-moe-wide", family="moe", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
        max_seq_len=MAX_LEN,
        moe=MoEConfig(n_experts=16, top_k=1, expert_d_ff=32)).validate()


def _prefix_trace(kind: str, smoke: bool, seed: int = 3,
                  output_len: int = 2, rate: float = 0.6,
                  prefix_pages: int = 12):
    """Shared-prefix workload (two system prompts of ``prefix_pages``
    KV pages + 4 fresh suffix tokens => ~98% token reuse within a prefix
    at the default 192+4) or the zero-reuse control with identical
    arrivals and shapes but fresh random prompts ("unique" — the
    no-regression baseline).  The default rate puts the COLD run right at
    its service capacity so the cache's faster prefill also drains the
    queue — the TTFT contrast production prefix reuse buys."""
    trace = shared_prefix_trace(
        16 if smoke else 28, n_prefixes=2,
        prefix_len=prefix_pages * PFX_PAGE,
        suffix_len=PFX_PAGE // 4, output_len=output_len, rate=rate,
        zipf_alpha=1.0, vocab_size=200, seed=seed)
    if kind == "unique":
        trace = attach_prompt_tokens(trace, 200, seed=seed + 1)
    return trace


def run_prefix(cfg: ModelConfig, model, params, trace_name: str, trace,
               cache_on: bool, mode: str, pages=None,
               decode_reserve=None) -> dict:
    """Open-loop replay (iteration clock — deterministic on CPU) of one
    trace through a fresh engine; TTFT is in iterations, expert bytes sum
    the real per-iteration ``iter_log`` counters."""
    sched = make_scheduler("layered", model.n_blocks, n_slots=4,
                           quantum=PFX_PAGE, token_budget=512)
    eng = Engine(model, params, sched, n_slots=4, max_len=MAX_LEN,
                 packed=True, pages=pages, page_size=PFX_PAGE,
                 preemption=True, preemption_mode=mode,
                 host_pages=4 * pages if pages and mode == "swap" else None,
                 decode_reserve=decode_reserve,
                 prefix_cache=cache_on)
    runtime = ServingRuntime(EngineExecutor(eng), clock="iteration")
    runtime.run(trace, max_iterations=20_000)
    m = request_metrics(eng.requests.values())
    return {
        "config": cfg.name, "trace": trace_name, "mode": mode,
        "prefix_cache": cache_on, "n_requests": len(trace),
        "n_iterations": eng.iteration,
        "ttft_mean": m["ttft_mean"],
        "prefill_tokens": sum(r["prefill_tokens"] for r in eng.iter_log),
        "prefill_dispatches": eng.n_prefill_dispatches,
        "dispatches_saved": 0,     # filled against the cache-off pair row
        "expert_load_mb": sum(r["expert_load_bytes"]
                              for r in eng.iter_log) / 1e6,
        "prefix_hit_rate": m["prefix_hit_rate"],
        "cached_tokens": eng.alloc.n_prefix_tokens,
        "n_preempted": eng.n_preempted,
        "n_swapped_out": eng.n_swapped_out,
        "_outputs": {int(r): list(v) for r, v in eng.outputs.items()},
    }


def run_prefix_cost_check(smoke: bool) -> dict:
    """Hit-rate-aware cost model vs the real engine: drain a shared-prefix
    burst with caching ON and price every executed plan through the same
    ``iteration_cost`` commit path the fig3 sweeps use — cached prefix
    tokens never appear in the plan's prefill rectangles, so the model
    prices only the uncached tails.  Runs on the 4-expert top-2 config
    where router coverage saturates at bench token counts, isolating the
    hit-aware rectangle accounting from coverage-expectation noise: the
    acceptance band is +/-5%."""
    cfg = _cfg_moe(smoke)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler("layered", model.n_blocks, n_slots=4,
                           quantum=PFX_PAGE, token_budget=512)
    eng = Engine(model, params, sched, n_slots=4, max_len=MAX_LEN,
                 packed=True, page_size=PFX_PAGE, prefix_cache=True)
    bp = eng._expert_bytes // max(cfg.expert_bytes(1), 1)
    cm = CostModel(cfg, H100X2, bytes_per_param=bp, moe_dispatch="ragged")
    for tr in _prefix_trace("shared", smoke):
        eng.submit(list(tr.prompt_tokens), tr.output_len)
    predicted = 0.0
    while eng.scheduler.has_work():
        plan = eng.scheduler.next_plan(now=float(eng.iteration))
        snap = {r: copy.copy(eng.requests[r]) for r in plan.decode_ids}
        eng.execute_plan(plan)
        predicted += cm.iteration_cost(plan, snap)["expert_bytes"]
    measured = float(sum(row["expert_load_bytes"] for row in eng.iter_log))
    # allocator counters, not request_metrics: the closed-loop drain never
    # stamps first_token_time (timestamps are the runtime's job)
    admitted = sum(r.admitted_prompt_tokens for r in eng.requests.values())
    hit_rate = eng.alloc.n_prefix_tokens / max(admitted, 1)
    return {"config": cfg.name, "prefix_hit_rate": hit_rate,
            "predicted_expert_mb": predicted / 1e6,
            "measured_expert_mb": measured / 1e6,
            "ratio": predicted / max(measured, 1.0)}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: one dense config, smaller burst")
    ap.add_argument("--spec", choices=["off", "ngram", "draft"],
                    default="ngram",
                    help="drafter for the decode-path rows; 'off' skips "
                         "the speculation section entirely")
    args = ap.parse_args(argv)

    cfgs = [_cfg_dense(args.smoke)]
    if not args.smoke:
        cfgs.append(_cfg_moe(args.smoke))
    jobs = _jobs(args.smoke)

    rows = []
    for cfg in cfgs:
        for sched in ("chunked", "layered"):
            for packed in (False, True):
                rows.append(run_one(cfg, sched, packed, jobs))

    def pair(cfg_name, sched):
        ps = next(r for r in rows if r["config"] == cfg_name
                  and r["scheduler"] == sched and not r["packed"])
        pk = next(r for r in rows if r["config"] == cfg_name
                  and r["scheduler"] == sched and r["packed"])
        return ps, pk

    pairs = [pair(c.name, s) for c in cfgs for s in ("chunked", "layered")]
    checks = {
        # CI gate: packing must never dispatch MORE executables
        "packed_never_more_dispatches": all(
            pk["dispatches_per_iter"] <= ps["dispatches_per_iter"] + 1e-9
            for ps, pk in pairs),
        # the acceptance bar: >= 2x fewer dispatches per iteration for the
        # layered cohorts at >= 4 co-resident prefills
        "packed_2x_fewer_dispatches_layered": all(
            pk["n_dispatches"] * 2 <= ps["n_dispatches"]
            for ps, pk in pairs if pk["scheduler"] == "layered"
            and pk["cohort_prefills"] >= 4),
        "layered_cohort_at_least_4": any(
            pk["cohort_prefills"] >= 4 for _, pk in pairs
            if pk["scheduler"] == "layered"),
        # cohorts compile one executable per group; per-slice compiles one
        # per (group, P-bucket).  Chunked is excluded: its B=2 emit pairs
        # are shapes the per-slice path never traces at all.
        "packed_compiles_no_more_executables_layered": all(
            pk["prefill_compiles"] <= ps["prefill_compiles"]
            for ps, pk in pairs if pk["scheduler"] == "layered"),
        # bit-identical generation on both passes of every run
        "tokens_identical_packed_vs_slice": all(
            pk["_outputs"] == ps["_outputs"]
            and pk["_outputs2"] == ps["_outputs2"]
            for ps, pk in pairs),
        # donated cache buffers: the packed path must not hold materially
        # more live device memory than per-slice (the packed stash is one
        # batch instead of B rows; headroom covers allocator slack)
        "donation_bounds_live_bytes": all(
            pk["peak_live_mb"] <= ps["peak_live_mb"] * 1.25 + 1.0
            for ps, pk in pairs),
    }
    # wall-clock is CPU-noisy: tracked as a soft (non-gating) trajectory
    # signal with headroom; the JSON keeps the raw numbers per PR
    soft_checks = {
        "packed_wall_no_worse": all(
            pk["ms_per_iter"] <= ps["ms_per_iter"] * 1.10
            for ps, pk in pairs),
    }

    # ---- decode path: speculative verify-k economics (schema v2)
    spec_rows, cost_check = [], None
    if args.spec != "off":
        cfg_d = _cfg_dense(args.smoke)
        for trace in ("repetitive", "adversarial"):
            jobs_d = _decode_jobs(trace, args.smoke)
            for spec in ("off", args.spec):
                spec_rows.append(run_decode(cfg_d, spec, trace, jobs_d))
        cost_check = run_cost_check(args.smoke, args.spec)

        def drow(trace, spec):
            return next(r for r in spec_rows
                        if r["trace"] == trace and r["spec"] == spec)

        rep_off, rep_on = drow("repetitive", "off"), \
            drow("repetitive", args.spec)
        adv_off, adv_on = drow("adversarial", "off"), \
            drow("adversarial", args.spec)
        checks.update({
            # the acceptance bar: >= 1.5x tokens per dispatch when the
            # drafter can see the pattern
            "spec_speedup_on_repetitive":
                rep_on["tokens_per_dispatch"]
                >= 1.5 * rep_off["tokens_per_dispatch"],
            # iteration-clock TBT floor: a failed verify still commits one
            # token per iteration, so even the 0-acceptance trace must not
            # stretch the token cadence
            "spec_no_tbt_regression_adversarial":
                adv_on["iters_per_token"]
                <= adv_off["iters_per_token"] + 1e-9,
            # speculation never changes token VALUES, on either trace
            "spec_tokens_identical":
                rep_on["_outputs"] == rep_off["_outputs"]
                and adv_on["_outputs"] == adv_off["_outputs"],
            "spec_engaged_on_repetitive":
                rep_on["acceptance_rate"] >= 0.5
                and rep_on["verify_dispatches"] > 0,
            # acceptance-adjusted expert-load prediction tracks the real
            # router-union counter (band covers expectation-vs-one-router
            # noise; observed ~0.98 on these shapes)
            "cost_model_tracks_engine_expert_bytes":
                0.6 <= cost_check["ratio"] <= 1.5,
        })

    # ---- prefix cache: shared-prefix reuse vs the zero-reuse control,
    # cache on vs off, both preemption flavours (schema v3).  The swap
    # rows run on a deliberately tight pool so eviction really fires.
    cfg_p = _cfg_moe_wide()
    model_p = DecoderModel(cfg_p)
    params_p = model_p.init(jax.random.PRNGKey(0))
    shared = _prefix_trace("shared", args.smoke)
    unique = _prefix_trace("unique", args.smoke)
    # the swap pair runs a longer-decode variant on a 20-page pool with no
    # decode reserve, so decode growth exhausts the pool and eviction
    # REALLY fires — the regime where shared pages must stay pinned
    swappy = _prefix_trace("shared", args.smoke, output_len=24, rate=0.35,
                           prefix_pages=7)
    prefix_rows = []
    for trace_name, trace, mode, pages, reserve in (
            ("shared", shared, "recompute", None, None),
            ("unique", unique, "recompute", None, None),
            ("shared", swappy, "swap", 20, 0)):
        for cache_on in (False, True):
            prefix_rows.append(run_prefix(cfg_p, model_p, params_p,
                                          trace_name, trace, cache_on,
                                          mode, pages=pages,
                                          decode_reserve=reserve))
    prefix_cost = run_prefix_cost_check(args.smoke)

    def prow(trace_name, mode, cache_on):
        return next(r for r in prefix_rows if r["trace"] == trace_name
                    and r["mode"] == mode and r["prefix_cache"] == cache_on)

    sh_off = prow("shared", "recompute", False)
    sh_on = prow("shared", "recompute", True)
    un_off = prow("unique", "recompute", False)
    un_on = prow("unique", "recompute", True)
    sw_off = prow("shared", "swap", False)
    sw_on = prow("shared", "swap", True)
    for off, on in ((sh_off, sh_on), (un_off, un_on), (sw_off, sw_on)):
        on["dispatches_saved"] = (off["prefill_dispatches"]
                                  - on["prefill_dispatches"])
    checks.update({
        # the trace reuses >= 70% of its tokens; the cache must see it
        "prefix_hit_on_shared": sh_on["prefix_hit_rate"] > 0,
        # the acceptance bars: mean TTFT halves and iter_log expert-load
        # bytes drop >= 30% on the reuse-heavy trace
        "prefix_ttft_2x": 2 * sh_on["ttft_mean"] <= sh_off["ttft_mean"],
        "prefix_expert_bytes_30pct":
            sh_on["expert_load_mb"] <= 0.7 * sh_off["expert_load_mb"],
        # zero-reuse control: lookup/registration must be dispatch-free
        "prefix_no_dispatch_regression":
            un_on["prefill_dispatches"] <= un_off["prefill_dispatches"],
        # token streams bit-identical cache on vs off, BOTH eviction modes
        "prefix_tokens_identical_recompute":
            sh_on["_outputs"] == sh_off["_outputs"]
            and un_on["_outputs"] == un_off["_outputs"],
        "prefix_tokens_identical_swap":
            sw_on["_outputs"] == sw_off["_outputs"],
        # hit-aware cost model within 5% of the engine's expert counter
        "prefix_cost_model_5pct": 0.95 <= prefix_cost["ratio"] <= 1.05,
    })

    for r in rows:
        r.pop("_outputs"), r.pop("_outputs2")
    print(table(rows, COLUMNS, "Engine iteration hot path — packed "
                               "layer-group batches vs per-slice"))
    if spec_rows:
        for r in spec_rows:
            r.pop("_outputs")
        print()
        print(table(spec_rows, SPEC_COLUMNS,
                    "Decode path — speculative verify-k "
                    f"(drafter: {args.spec})"))
        print("\ncost-model cross-check:", cost_check)
    for r in prefix_rows:
        r.pop("_outputs")
    print()
    print(table(prefix_rows, PREFIX_COLUMNS,
                "Prefix cache — shared-prefix reuse vs zero-reuse control "
                "(open-loop, iteration clock)"))
    print("\nprefix cost-model cross-check:", prefix_cost)
    print("\nchecks:", checks)
    print("soft checks (non-gating):", soft_checks)
    res = {
        "schema": "bench-trajectory-v3",
        "bench": "engine_iter_bench",
        "smoke": args.smoke,
        "columns": COLUMNS,
        "rows": rows,
        "spec_mode": args.spec,
        "spec_columns": SPEC_COLUMNS,
        "spec_rows": spec_rows,
        "cost_model_check": cost_check,
        "prefix_columns": PREFIX_COLUMNS,
        "prefix_rows": prefix_rows,
        "prefix_cost_check": prefix_cost,
        "checks": checks,
        "soft_checks": soft_checks,
        "pass": all(checks.values()),
    }
    save("engine_iter_bench", res)
    return res


if __name__ == "__main__":
    main()
