"""Roofline aggregation: reads the dry-run artifacts (experiments/dryrun)
and prints the per-(arch x shape) three-term roofline table — the source of
EXPERIMENTS.md §Roofline. Also selects the three hillclimb pairs."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save, table

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_reports(mesh: str = "16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("status") == "skipped":
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "status": "skipped", "reason": d["reason"][:40]})
            continue
        if d.get("status") != "ok" or "t_compute_s" not in d:
            continue
        tc, tm, tcoll = d["t_compute_s"], d["t_memory_s"], d["t_collective_s"]
        dom = max(tc, tm, tcoll)
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "status": "ok",
            "t_compute_s": tc, "t_memory_s": tm, "t_collective_s": tcoll,
            "bottleneck": d["bottleneck"],
            "useful_flops_ratio": d.get("useful_flops_ratio", 0.0),
            "roofline_frac": (max(tc, tm) / dom if dom else 0.0),
            "mem_gb": (d.get("peak_memory_per_device") or 0) / 1e9,
        })
    return rows


def main() -> dict:
    rows = load_reports()
    ok = [r for r in rows if r["status"] == "ok"]
    print(table(ok, ["arch", "shape", "t_compute_s", "t_memory_s",
                     "t_collective_s", "bottleneck", "useful_flops_ratio",
                     "mem_gb"],
                "Roofline terms per (arch x shape), 16x16 single pod"))
    skipped = [r for r in rows if r["status"] == "skipped"]
    if skipped:
        print("\nskipped (documented in DESIGN.md §Shape skips):")
        for r in skipped:
            print(f"  {r['arch']} x {r['shape']}: {r['reason']}...")

    # hillclimb selection: worst useful-flops ratio, most collective-bound,
    # most representative of the paper (MoE decode)
    by_useful = sorted(ok, key=lambda r: r["useful_flops_ratio"])
    coll_bound = sorted(ok, key=lambda r: -(r["t_collective_s"]
                                            / max(r["t_compute_s"],
                                                  r["t_memory_s"], 1e-12)))
    checks = {"n_ok": len(ok), "n_skipped": len(skipped),
              "all_combos_accounted": len(ok) + len(skipped) == 40}
    print("\nworst useful-flops:", [(r["arch"], r["shape"]) for r in
                                    by_useful[:3]])
    print("most collective-bound:", [(r["arch"], r["shape"]) for r in
                                     coll_bound[:3]])
    print("checks:", checks)
    result = {"rows": rows, "checks": checks,
              "pass": checks["all_combos_accounted"]}
    save("roofline", result)
    return result


if __name__ == "__main__":
    main()
