"""Microbench: ragged dropless MoE dispatch vs the dense worst-case
capacity buffer (the tentpole of the ragged-GMM PR).

Two halves:

1. MODELED (CostModel.moe_gmm_cost, qwen3-30b-a3b geometry, E=128): expert
   GMM rows / FLOPs / weight bytes across top_k ∈ {1, 2, 8} and
   T ∈ {128, 2048, 32768}. Checks the paper-level claims: ragged work
   scales with sum(expert_counts) (→ top_k/E of the dense dropless buffer
   once every expert is covered) and the ragged weight-traffic term is
   exactly active_experts × bytes_per_expert.

2. MEASURED (real routing + both jnp data paths on CPU, small synthetic
   model): wall time of apply-level dense vs ragged dispatch, plus a
   data-path check that the ragged tile metadata streams exactly the
   active experts (distinct tile owners == experts with >= 1 token).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, table
from repro.configs import get_config
from repro.models.config import MoEConfig, ModelConfig
from repro.models import moe
from repro.serving.cost_model import H100X2, CostModel

TOP_KS = (1, 2, 8)
TOKENS = (128, 2048, 32768)

# measured half: small enough for CPU, big enough to see the row ratio
MEAS_E, MEAS_D, MEAS_F = 32, 64, 128
MEAS_T = 2048


def modeled_sweep(base: ModelConfig):
    rows = []
    for k in TOP_KS:
        cfg = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, top_k=k))
        cm = CostModel(cfg, H100X2)
        eb = cfg.expert_bytes(cm.bp)
        for t in TOKENS:
            r = cm.moe_gmm_cost(t, "ragged")
            d = cm.moe_gmm_cost(t, "dense")
            rows.append({
                "top_k": k, "tokens": t,
                "ragged_rows": r["rows"], "dense_rows": d["rows"],
                "row_ratio": r["rows"] / d["rows"],
                "flops_ratio": r["flops"] / d["flops"],
                "ragged_weight_gb": r["weight_bytes"] / 1e9,
                "dense_weight_gb": d["weight_bytes"] / 1e9,
                "active_experts": r["active_experts"],
                "weight_eq_active_x_expert": bool(np.isclose(
                    r["weight_bytes"], r["active_experts"] * eb)),
            })
    return rows


def _tiny_moe_cfg(top_k: int) -> ModelConfig:
    return ModelConfig(
        name=f"bench-moe-k{top_k}", family="moe", n_layers=1,
        d_model=MEAS_D, n_heads=4, n_kv_heads=4, d_ff=MEAS_F,
        vocab_size=256, max_seq_len=MEAS_T,
        moe=MoEConfig(n_experts=MEAS_E, top_k=top_k,
                      expert_d_ff=MEAS_F)).validate()


def _time(fn, *args) -> float:
    fn(*args)[0].block_until_ready()          # compile + warm
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        fn(*args)[0].block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def measured_sweep():
    rows = []
    for k in TOP_KS:
        cfg = _tiny_moe_cfg(k)
        p = moe.init_moe(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, MEAS_T, MEAS_D))

        dense = jax.jit(lambda p_, x_: moe.apply_moe(
            cfg, p_, x_, dropless=True))
        ragged = jax.jit(lambda p_, x_: moe.apply_moe(
            cfg, p_, x_, moe_dispatch="ragged"))
        t_dense = _time(dense, p, x)
        t_ragged = _time(ragged, p, x)

        out_d, aux_d = dense(p, x)
        out_r, aux_r = ragged(p, x)
        max_err = float(jnp.abs(out_d - out_r).max())

        # data-path check: the ragged tile metadata streams exactly the
        # active experts' weight blocks
        idx, _, _ = moe.route(cfg, p, x.reshape(-1, MEAS_D))
        m_blk, n_rows = moe.ragged_tile_rows(idx.size, MEAS_E)
        _, _, counts, tile_expert = moe.ragged_dispatch_indices(
            idx, MEAS_E, m_blk, n_rows)
        active = int((np.asarray(counts) > 0).sum())
        streamed = len({int(e) for e in np.asarray(tile_expert)
                        if e < MEAS_E})
        rows.append({
            "top_k": k, "tokens": MEAS_T,
            "dense_ms": t_dense * 1e3, "ragged_ms": t_ragged * 1e3,
            "speedup": t_dense / t_ragged,
            "max_err": max_err,
            "active_experts": active, "tile_streamed_experts": streamed,
        })
    return rows


def main() -> dict:
    base = get_config("qwen3-30b-a3b")
    mod = modeled_sweep(base)
    print(table(mod, ["top_k", "tokens", "ragged_rows", "dense_rows",
                      "row_ratio", "flops_ratio", "ragged_weight_gb",
                      "dense_weight_gb"],
                "Ragged vs dense dropless expert GMM — modeled "
                f"({base.name}, E={base.moe.n_experts})"))
    meas = measured_sweep()
    print(table(meas, ["top_k", "tokens", "dense_ms", "ragged_ms",
                       "speedup", "max_err", "active_experts",
                       "tile_streamed_experts"],
                f"Measured (CPU, jnp paths, E={MEAS_E}, d={MEAS_D}, "
                f"T={MEAS_T})"))

    e = base.moe.n_experts
    by = {(r["top_k"], r["tokens"]): r for r in mod}
    checks = {
        # once coverage saturates, ragged work ~= top_k/E of dense (plus
        # <= one tile of alignment padding per expert)
        "flops_scale_with_routed_work": all(
            k / e <= by[(k, 32768)]["flops_ratio"] <= 1.5 * k / e + 0.01
            for k in TOP_KS),
        # ragged weight traffic == active_experts × bytes_per_expert
        "weight_bytes_eq_active_experts": all(
            r["weight_eq_active_x_expert"] for r in mod),
        # the real tile metadata streams exactly the active experts
        "tile_metadata_streams_active_only": all(
            r["active_experts"] == r["tile_streamed_experts"]
            for r in meas),
        # both data paths agree numerically
        "paths_agree": all(r["max_err"] < 1e-4 for r in meas),
        # fewer rows must win wall-clock where the gap is largest
        "ragged_faster_at_low_topk": all(
            r["speedup"] > 1.0 for r in meas if r["top_k"] <= 2),
    }
    ok = all(checks.values())
    print("\nchecks:", checks)
    res = {"modeled": mod, "measured": meas, "checks": checks, "pass": ok}
    save("gmm_ragged_vs_dense", res)
    return res


if __name__ == "__main__":
    main()
