"""Paper Table 8: energy per token + latency at steady-state SLO-compliant
operating points on arXiv.

Paper (Qwen):  chunked @1.3 -> 56.6 J/tok*; layered @1.3 -> 51.7 (-9%);
               layered @1.6 -> 44.2 (-22%), i.e. +23% usable capacity.
Paper (GPT):   chunked @2.1 -> 37.4; layered @2.1 -> 34.3 (-8%);
               layered @2.7 -> 29.8 (-20%), +29% capacity.
(*paper's units are mJ/tok in Table 2 and J/tok in Table 8; magnitudes
match mJ/tok — we report mJ/tok.)
"""

from __future__ import annotations

from benchmarks.common import run_sim, save, table

POINTS = [
    # (model, sched, rate)
    ("qwen3-30b-a3b", "chunked", 1.3),
    ("qwen3-30b-a3b", "layered", 1.3),
    ("qwen3-30b-a3b", "layered", 1.6),
    ("gpt-oss-20b", "chunked", 2.1),
    ("gpt-oss-20b", "layered", 2.1),
    ("gpt-oss-20b", "layered", 2.7),
]

PAPER_MJ = {("qwen3-30b-a3b", "chunked", 1.3): 56.6,
            ("qwen3-30b-a3b", "layered", 1.3): 51.7,
            ("qwen3-30b-a3b", "layered", 1.6): 44.2,
            ("gpt-oss-20b", "chunked", 2.1): 37.4,
            ("gpt-oss-20b", "layered", 2.1): 34.3,
            ("gpt-oss-20b", "layered", 2.7): 29.8}


def main(n_requests: int = 120) -> dict:
    rows = []
    got = {}
    for model, sched, rate in POINTS:
        m, _ = run_sim(model, "arxiv", sched, rate, n_requests=n_requests)
        got[(model, sched, rate)] = m["energy_per_token_mj"]
        rows.append({
            "model": model.split("-")[0], "sched": sched, "rate": rate,
            "ttft_mean": m["ttft_mean"], "tbt_mean_ms": m["tbt_mean"] * 1e3,
            "mj_tok": m["energy_per_token_mj"],
            "paper_mj": PAPER_MJ[(model, sched, rate)],
            "slo": m["slo_attainment"],
        })
    print(table(rows, ["model", "sched", "rate", "ttft_mean", "tbt_mean_ms",
                       "mj_tok", "paper_mj", "slo"],
                "Table 8 — energy per output token (arXiv)"))
    q, g = "qwen3-30b-a3b", "gpt-oss-20b"
    same_rate_q = got[(q, "layered", 1.3)] / got[(q, "chunked", 1.3)] - 1
    high_rate_q = got[(q, "layered", 1.6)] / got[(q, "chunked", 1.3)] - 1
    same_rate_g = got[(g, "layered", 2.1)] / got[(g, "chunked", 2.1)] - 1
    high_rate_g = got[(g, "layered", 2.7)] / got[(g, "chunked", 2.1)] - 1
    checks = {
        # same-rate savings (paper -8..-9%); accept -4% or better
        "qwen_same_rate_saves": same_rate_q < -0.04,
        "gpt_same_rate_saves": same_rate_g < -0.04,
        # higher sustainable rate still cheaper than chunked baseline
        "qwen_high_rate_saves_more": high_rate_q < same_rate_q,
        "gpt_high_rate_saves_more": high_rate_g < same_rate_g,
        # layered at the higher rate remains SLO-compliant (>=90%)
        "qwen_high_rate_slo": [r for r in rows if r["model"] == "qwen3" and
                               r["rate"] == 1.6][0]["slo"] >= 0.9,
    }
    print("\nsavings: qwen same-rate "
          f"{same_rate_q:+.1%} (paper -9%), high-rate {high_rate_q:+.1%} "
          f"(paper -22%); gpt same-rate {same_rate_g:+.1%} (paper -8%), "
          f"high-rate {high_rate_g:+.1%} (paper -20%)")
    print("checks:", checks)
    result = {"rows": rows,
              "savings": {"qwen_same": same_rate_q, "qwen_high": high_rate_q,
                          "gpt_same": same_rate_g, "gpt_high": high_rate_g},
              "checks": checks, "pass": all(checks.values())}
    save("table8_energy", result)
    return result


if __name__ == "__main__":
    main()
