"""Shared benchmark plumbing: result recording, table printing, and the
standard simulator configuration used across the paper reproductions."""

from __future__ import annotations

import json
import math
import os
import time
from typing import Dict, List

from repro.configs import get_config
from repro.launch.config import ServeConfig
from repro.serving.cost_model import H100X2
from repro.serving.metrics import SLOConfig, per_class_metrics, request_metrics
from repro.serving.simulator import Simulator
from repro.serving.traffic import DATASETS, poisson_trace

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "results")

# Paper Table 5 SLOs.
SLOS = {
    ("qwen3-30b-a3b", "sharegpt"): SLOConfig(5.0, 0.125),
    ("qwen3-30b-a3b", "arxiv"): SLOConfig(10.0, 0.125),
    ("gpt-oss-20b", "sharegpt"): SLOConfig(5.0, 0.100),
    ("gpt-oss-20b", "arxiv"): SLOConfig(10.0, 0.100),
}

N_SLOTS = 128

# Oversubscribed operating point: the page pool holds ~this many
# average-size residents — far below N_SLOTS, so admission queues and the
# pressure pass really evicts (the regime PR 2's machinery targets).
OVERSUBSCRIBED_RESIDENTS = 3


def oversubscribed_pages(model: str, trace, page_size: int = 16,
                         residents: int = OVERSUBSCRIBED_RESIDENTS) -> int:
    """Pool size (pages) holding ~``residents`` average requests of this
    trace, floored so the single biggest request still fits an empty pool
    (admission would otherwise reject it outright).  Per-request need =
    full-sequence KV + the layered stash charge at the prompt length."""
    cfg = get_config(model)
    sf = cfg.stash_token_factor()
    need = [math.ceil((t.prompt_len + t.output_len) / page_size)
            + math.ceil(math.ceil(t.prompt_len * sf) / page_size)
            for t in trace]
    mean_pool = int(residents * sum(need) / len(need))
    # +2 pages of slack: decode-reserve rounding on top of the worst request
    return max(mean_pool, max(need) + 2)


def run_sim(model: str, dataset: str, scheduler: str, rate: float,
            n_requests: int = 100, seed: int = 0, **sched_kw):
    trace = poisson_trace(DATASETS[dataset], rate, n_requests, seed=seed)
    m, res, _ = run_sim_trace(model, trace, scheduler,
                              slo=SLOS.get((model, dataset)), **sched_kw)
    m.update({"dataset": dataset, "rate": rate})
    return m, res


def run_sim_trace(model: str, trace, scheduler: str, slo=None, **sched_kw):
    """Run an externally built trace (e.g. a multi-class mix) through the
    standard simulator configuration.  ``slo`` may be a single SLOConfig
    or a per-class dict; returns (aggregate metrics, SimResult,
    per-class metrics)."""
    cfg = get_config(model)
    # the standard configuration is ONE ServeConfig (launch/config.py) —
    # the same defaults serve.py and the load generator run under —
    # specialized only by the paper's benchmark batch shape; per-point
    # overrides then layer on top in the Simulator kwarg namespace
    base = ServeConfig(arch=model, scheduler=scheduler, simulate=True,
                       slots=N_SLOTS, token_budget=512,
                       quantum=512).validate()
    defaults = base.sim_kwargs()
    defaults.update(sched_kw)
    if defaults.pop("oversubscribed", False):
        defaults.setdefault(
            "n_pages", oversubscribed_pages(
                model, trace, defaults.get("page_size", 16)))
    sim = Simulator(cfg, scheduler, H100X2, **defaults)
    res = sim.run(trace)
    agg_slo = None if isinstance(slo, dict) else slo
    m = request_metrics(res.requests, agg_slo)
    m.update({
        "model": model, "scheduler": scheduler,
        "energy_per_token_mj": res.energy_per_token * 1e3,
        "expert_bytes_total": res.total_expert_bytes,
        "mean_decode_batch": res.mean_decode_batch,
        "n_iterations": res.n_iterations,
        # memory-subsystem signals (nonzero only under a bounded pool)
        "recompute_tokens": res.recompute_tokens,
        "swap_bytes": res.swap_bytes,
        "swap_dma_time": res.swap_dma_time,
        "swap_stall_time": res.swap_stall_time,
        "pages_high_water": res.pages_high_water,
    })
    return m, res, per_class_metrics(res.requests, slo)


def save(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    return path


def table(rows: List[Dict], cols: List[str], title: str = "") -> str:
    out = []
    if title:
        out.append(title)
    widths = [max(len(c), *(len(_fmt(r.get(c))) for r in rows))
              for c in cols]
    out.append("  ".join(c.ljust(w) for c, w in zip(cols, widths)))
    out.append("  ".join("-" * w for w in widths))
    for r in rows:
        out.append("  ".join(_fmt(r.get(c)).ljust(w)
                             for c, w in zip(cols, widths)))
    return "\n".join(out)


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.3f}" if abs(v) < 10 else f"{v:.1f}"
    return str(v)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.elapsed = time.time() - self.t0
