"""Paper Table 6: Qwen on arXiv at 1.3 req/s — TTFT/TBT mean and p99 for
chunked vs layered. Paper: chunked 2.803/8.651 s TTFT, 32.9/51.1 ms TBT;
layered 1.237/4.098 s TTFT, 21.5/37.1 ms TBT.
"""

from __future__ import annotations

from benchmarks.common import run_sim, save, table

PAPER = {
    "chunked": {"ttft_mean": 2.803, "ttft_p99": 8.651,
                "tbt_mean_ms": 32.9, "tbt_p99_ms": 51.1},
    "layered": {"ttft_mean": 1.237, "ttft_p99": 4.098,
                "tbt_mean_ms": 21.5, "tbt_p99_ms": 37.1},
}


def main(n_requests: int = 150) -> dict:
    rows = []
    got = {}
    for sched in ("chunked", "layered"):
        m, _ = run_sim("qwen3-30b-a3b", "arxiv", sched, 1.3,
                       n_requests=n_requests)
        got[sched] = {"ttft_mean": m["ttft_mean"], "ttft_p99": m["ttft_p99"],
                      "tbt_mean_ms": m["tbt_mean"] * 1e3,
                      "tbt_p99_ms": m["tbt_p99"] * 1e3,
                      "e2e_mean": m["e2e_mean"]}
        rows.append({"sched": sched, **got[sched],
                     **{f"paper_{k}": v for k, v in PAPER[sched].items()}})
    print(table(rows, ["sched", "ttft_mean", "paper_ttft_mean", "ttft_p99",
                       "paper_ttft_p99", "tbt_mean_ms", "paper_tbt_mean_ms",
                       "tbt_p99_ms", "paper_tbt_p99_ms", "e2e_mean"],
                "Table 6 — Qwen on arXiv @1.3 req/s"))
    ttft_ratio = got["layered"]["ttft_mean"] / got["chunked"]["ttft_mean"]
    paper_ratio = PAPER["layered"]["ttft_mean"] / PAPER["chunked"]["ttft_mean"]
    checks = {
        # paper: mean TTFT drops >50% at the same rate
        "ttft_halved": ttft_ratio < 0.55,
        "ttft_ratio_matches_paper": abs(ttft_ratio - paper_ratio) < 0.15,
        "tbt_mean_lower": got["layered"]["tbt_mean_ms"]
        < got["chunked"]["tbt_mean_ms"],
        "tails_tighter": got["layered"]["ttft_p99"]
        < got["chunked"]["ttft_p99"],
    }
    print(f"\nTTFT ratio layered/chunked: {ttft_ratio:.2f} "
          f"(paper {paper_ratio:.2f})")
    print("checks:", checks)
    result = {"rows": rows, "ttft_ratio": ttft_ratio,
              "paper_ratio": paper_ratio, "checks": checks,
              "pass": all(checks.values())}
    save("table6_latency", result)
    return result


if __name__ == "__main__":
    main()
