"""Paper Table 7: total expert-weight loads for 100 requests on Qwen,
chunked vs layered, ShareGPT and arXiv.

Paper: ShareGPT 28.5 -> 25.1 TB (-12%); arXiv 35.6 -> 21.7 TB (-39%).
The headline mechanism claim: the reduction is much larger on long-prompt
workloads, and layered always reduces.
"""

from __future__ import annotations

from benchmarks.common import run_sim, save, table

RATES = {"sharegpt": 4.4, "arxiv": 1.3}
PAPER = {"sharegpt": -0.120, "arxiv": -0.390}


def main(n_requests: int = 100) -> dict:
    rows = []
    reductions = {}
    for dataset, rate in RATES.items():
        loads = {}
        for sched in ("chunked", "layered"):
            m, res = run_sim("qwen3-30b-a3b", dataset, sched, rate,
                             n_requests=n_requests)
            loads[sched] = m["expert_bytes_total"]
            rows.append({"dataset": dataset, "sched": sched,
                         "total_tb": m["expert_bytes_total"] / 1e12})
        red = loads["layered"] / loads["chunked"] - 1.0
        reductions[dataset] = red
        rows.append({"dataset": dataset, "sched": "reduction",
                     "total_tb": red})
    print(table(rows, ["dataset", "sched", "total_tb"],
                "Table 7 — expert weight loads, 100 requests (Qwen)"))
    checks = {
        "layered_reduces_sharegpt": reductions["sharegpt"] < -0.05,
        "layered_reduces_arxiv": reductions["arxiv"] < -0.25,
        "arxiv_reduction_larger": reductions["arxiv"]
        < reductions["sharegpt"],
    }
    print("\nreductions:", {k: f"{v:+.1%}" for k, v in reductions.items()},
          "(paper: sharegpt -12%, arxiv -39%)")
    print("checks:", checks)
    result = {"rows": rows, "reductions": reductions, "paper": PAPER,
              "checks": checks, "pass": all(checks.values())}
    save("table7_expert_loads", result)
    return result


if __name__ == "__main__":
    main()
